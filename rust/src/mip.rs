//! Mixed-integer reuse-factor optimizer (Gurobi substitute — paper §IV-B).
//!
//! The deployment problem: for each layer i pick one reuse factor
//! R_i (a divisor of n_in·n_out), minimizing the summed predicted resource
//! cost (LUT+FF+BRAM+DSP) subject to the summed predicted latency staying
//! within the real-time budget (50,000 cycles = 200 µs at 250 MHz).
//!
//! With every feature fixed except the reuse factor, the random-forest
//! models collapse to per-(layer, R) constants (paper §IV-B), so the MIP is
//! exactly a **multiple-choice knapsack**: binary x_{i,j}, Σ_j x_{i,j} = 1,
//! min Σ c_{i,j} x_{i,j} s.t. Σ l_{i,j} x_{i,j} ≤ L.
//!
//! Two exact solvers are provided and cross-checked in the tests:
//!
//! * [`solve_bb`] — the Gurobi-shaped path: LP relaxation by a two-phase
//!   dense **simplex**, branch-and-bound on the most fractional layer,
//!   dominance pruning. This is what `N-TORC` timing claims run on.
//! * [`solve_dp`] — dynamic programming over the integer latency budget;
//!   slower but an independent oracle for the optimum.

use std::collections::HashMap;

/// One reuse-factor option for a layer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Choice {
    pub reuse: usize,
    pub cost: f64,
    pub latency: f64,
}

/// Inter-layer stream-buffer (FIFO) cost model.
///
/// On a dataflow target adjacent layers hand tokens over a stream; when
/// the producer's issue rate outruns the consumer's, the handoff needs a
/// skid buffer whose depth grows with the rate mismatch (StreamTensor's
/// inter-kernel FIFO sizing). The reuse factor *is* the rate knob here —
/// R-fold folding means one output token every ~R cycles — so each
/// adjacent choice pair implies a FIFO depth and a BRAM-equivalent cost:
///
/// ```text
/// depth(k, a, b) = min_depth + widths[k] · max(0, 1 − R_a / R_b)
/// cost(k, a, b)  = cost_per_slot · depth(k, a, b)
/// ```
///
/// where `a` produces into boundary `k` and `b` consumes from it. A
/// producer with *smaller* reuse (more parallel MACs, higher token rate)
/// than its consumer backs up and pays; matched or consumer-faster pairs
/// pay only the minimum skid depth.
#[derive(Clone, Debug, PartialEq)]
pub struct FifoModel {
    /// BRAM-equivalent cost of one buffered slot.
    pub cost_per_slot: f64,
    /// Skid depth every boundary pays regardless of rates.
    pub min_depth: f64,
    /// Stream width (elements per token) of each layer boundary;
    /// `widths.len() == n_layers − 1`.
    pub widths: Vec<f64>,
}

impl FifoModel {
    /// Uniform unit-width model over `n_layers − 1` boundaries.
    pub fn uniform(n_layers: usize, cost_per_slot: f64, min_depth: f64) -> FifoModel {
        FifoModel {
            cost_per_slot,
            min_depth,
            widths: vec![1.0; n_layers.saturating_sub(1)],
        }
    }

    /// BRAM-equivalent cost of the stream buffer at boundary `k`
    /// (between layers `k` and `k+1`) for a given producer/consumer
    /// choice pair. Latency is never affected — the buffer hides the
    /// rate mismatch, it does not serialize the pipeline.
    pub fn boundary_cost(&self, k: usize, producer: &Choice, consumer: &Choice) -> f64 {
        let (rp, rc) = (producer.reuse as f64, consumer.reuse as f64);
        let mismatch = if rc > 0.0 { (1.0 - rp / rc).max(0.0) } else { 0.0 };
        self.cost_per_slot * (self.min_depth + self.widths[k] * mismatch)
    }
}

/// A deployment instance.
#[derive(Clone, Debug)]
pub struct DeployProblem {
    /// Per-layer candidate choices (non-empty).
    pub layers: Vec<Vec<Choice>>,
    /// Total latency budget in cycles.
    pub latency_budget: f64,
    /// Optional inter-layer stream-buffer cost (None = free handoff,
    /// the shallow-plan default — keeps every PR 9 key/cost/document
    /// bit-identical).
    pub fifo: Option<FifoModel>,
}

/// A reuse-factor assignment.
#[derive(Clone, Debug, PartialEq)]
pub struct Solution {
    /// Index into `layers[i]` for each layer.
    pub pick: Vec<usize>,
    pub cost: f64,
    pub latency: f64,
}

impl DeployProblem {
    /// Total number of assignments (the paper's "RF permutations").
    pub fn permutations(&self) -> f64 {
        self.layers.iter().map(|l| l.len() as f64).product()
    }

    /// Canonical objective: separable per-layer cost plus, when a
    /// [`FifoModel`] is attached, the pairwise stream-buffer cost of
    /// every adjacent boundary. All solvers re-evaluate through here.
    pub fn evaluate(&self, pick: &[usize]) -> Solution {
        assert_eq!(pick.len(), self.layers.len());
        let mut cost = 0.0;
        let mut latency = 0.0;
        // Interleave each boundary term right after its consumer layer —
        // the exact accumulation order the frontier DP uses — so frontier
        // points canonicalize bit-identically through this summation.
        for (i, &j) in pick.iter().enumerate() {
            cost += self.layers[i][j].cost;
            if i > 0 {
                if let Some(f) = &self.fifo {
                    cost += f.boundary_cost(
                        i - 1,
                        &self.layers[i - 1][pick[i - 1]],
                        &self.layers[i][j],
                    );
                }
            }
            latency += self.layers[i][j].latency;
        }
        Solution { pick: pick.to_vec(), cost, latency }
    }

    /// The stream-buffer share of an assignment's cost (0.0 without a
    /// FIFO model) — the `fifo_bram` column in report sweeps.
    pub fn fifo_cost_of(&self, pick: &[usize]) -> f64 {
        let Some(f) = &self.fifo else { return 0.0 };
        let mut total = 0.0;
        for k in 0..pick.len().saturating_sub(1) {
            total += f.boundary_cost(
                k,
                &self.layers[k][pick[k]],
                &self.layers[k + 1][pick[k + 1]],
            );
        }
        total
    }

    pub fn is_feasible(&self, pick: &[usize]) -> bool {
        self.evaluate(pick).latency <= self.latency_budget + 1e-9
    }

    /// The same instance re-budgeted — the shape every per-budget
    /// re-solve (cross-checks, the [`crate::solver`] registry) takes,
    /// instead of a clone-then-mutate at each call site.
    pub fn with_budget(&self, latency_budget: f64) -> DeployProblem {
        DeployProblem {
            layers: self.layers.clone(),
            latency_budget,
            fifo: self.fifo.clone(),
        }
    }

    /// The same instance with a FIFO model attached.
    pub fn with_fifo(&self, fifo: FifoModel) -> DeployProblem {
        assert_eq!(
            fifo.widths.len(),
            self.layers.len().saturating_sub(1),
            "FifoModel widths must cover every adjacent boundary"
        );
        DeployProblem {
            layers: self.layers.clone(),
            latency_budget: self.latency_budget,
            fifo: Some(fifo),
        }
    }

    /// Remove dominated choices per layer (another choice has <= latency
    /// and <= cost, one strict). Returns the pruned problem and, per
    /// layer, the original index of each surviving choice.
    ///
    /// Only sound for the separable objective: with a [`FifoModel`]
    /// attached a per-layer-dominated choice can still win through its
    /// boundary terms, so FIFO-aware solvers must keep every choice
    /// (see [`prune_for_solve`](Self::prune_for_solve)).
    pub fn prune_dominated(&self) -> (DeployProblem, Vec<Vec<usize>>) {
        let mut layers = Vec::with_capacity(self.layers.len());
        let mut maps = Vec::with_capacity(self.layers.len());
        for choices in &self.layers {
            let mut order: Vec<usize> = (0..choices.len()).collect();
            // Sort by latency asc, then cost asc.
            order.sort_by(|&a, &b| {
                choices[a]
                    .latency
                    .partial_cmp(&choices[b].latency)
                    .unwrap()
                    .then(choices[a].cost.partial_cmp(&choices[b].cost).unwrap())
            });
            let mut kept: Vec<usize> = Vec::new();
            let mut best_cost = f64::INFINITY;
            for &j in &order {
                if choices[j].cost < best_cost - 1e-12 {
                    kept.push(j);
                    best_cost = choices[j].cost;
                }
            }
            maps.push(kept.clone());
            layers.push(kept.iter().map(|&j| choices[j]).collect());
        }
        (
            DeployProblem {
                layers,
                latency_budget: self.latency_budget,
                fifo: self.fifo.clone(),
            },
            maps,
        )
    }

    /// Dominance pruning gated on the objective: per-layer pruning when
    /// the cost is separable, identity (every choice kept) when a FIFO
    /// model makes adjacent choices interact.
    pub fn prune_for_solve(&self) -> (DeployProblem, Vec<Vec<usize>>) {
        if self.fifo.is_some() {
            let maps = self.layers.iter().map(|l| (0..l.len()).collect()).collect();
            (self.clone(), maps)
        } else {
            self.prune_dominated()
        }
    }

    /// Quick feasibility check: even the min-latency assignment must fit.
    pub fn min_latency(&self) -> f64 {
        self.layers
            .iter()
            .map(|l| l.iter().map(|c| c.latency).fold(f64::INFINITY, f64::min))
            .sum()
    }
}

// ---------------------------------------------------------------------------
// Two-phase dense simplex (min c.x, A_eq x = b_eq, A_ub x <= b_ub, x >= 0)
// ---------------------------------------------------------------------------

/// LP in standard inequality/equality form.
#[derive(Clone, Debug, Default)]
pub struct Lp {
    pub n: usize,
    pub c: Vec<f64>,
    pub a_eq: Vec<Vec<f64>>,
    pub b_eq: Vec<f64>,
    pub a_ub: Vec<Vec<f64>>,
    pub b_ub: Vec<f64>,
}

/// LP outcome.
#[derive(Clone, Debug, PartialEq)]
pub enum LpResult {
    Optimal { x: Vec<f64>, obj: f64 },
    Infeasible,
    Unbounded,
}

/// Two-phase primal simplex with Bland's rule (anti-cycling). Dense
/// tableau; sized for the MCKP relaxations this crate generates
/// (hundreds of columns, tens of rows).
pub fn solve_lp(lp: &Lp) -> LpResult {
    let n = lp.n;
    let m_ub = lp.a_ub.len();
    let m_eq = lp.a_eq.len();
    let m = m_ub + m_eq;
    // Columns: n structural + m_ub slack + m artificial; rows: m + 1 (obj).
    let n_slack = m_ub;
    let n_art = m;
    let cols = n + n_slack + n_art + 1; // + RHS
    let rhs_col = cols - 1;
    let mut t = vec![vec![0.0f64; cols]; m + 1];
    let mut basis = vec![0usize; m];

    // Fill rows: first the ub rows, then the eq rows; make RHS >= 0.
    for (r, (row, &b)) in lp.a_ub.iter().zip(&lp.b_ub).enumerate() {
        let sign = if b < 0.0 { -1.0 } else { 1.0 };
        for j in 0..n {
            t[r][j] = sign * row[j];
        }
        t[r][n + r] = sign; // slack (may flip to surplus with sign)
        t[r][rhs_col] = sign * b;
    }
    for (k, (row, &b)) in lp.a_eq.iter().zip(&lp.b_eq).enumerate() {
        let r = m_ub + k;
        let sign = if b < 0.0 { -1.0 } else { 1.0 };
        for j in 0..n {
            t[r][j] = sign * row[j];
        }
        t[r][rhs_col] = sign * b;
    }
    // Artificials on every row for a uniform phase-1 start.
    for r in 0..m {
        t[r][n + n_slack + r] = 1.0;
        basis[r] = n + n_slack + r;
    }

    // Phase 1 objective: minimize the sum of artificials. Reduced cost of
    // column j is c_j - z_j; the artificials are basic with cost 1, so
    // their reduced costs are 0 and every other column gets -(sum of its
    // constraint coefficients).
    for j in 0..cols {
        if (n + n_slack..n + n_slack + n_art).contains(&j) {
            t[m][j] = 0.0;
            continue;
        }
        let mut s = 0.0;
        for r in 0..m {
            s += t[r][j];
        }
        t[m][j] = -s;
    }

    fn pivot(t: &mut [Vec<f64>], basis: &mut [usize], row: usize, col: usize, cols: usize) {
        let m = basis.len();
        let p = t[row][col];
        for j in 0..cols {
            t[row][j] /= p;
        }
        for r in 0..=m {
            if r != row && t[r][col].abs() > 1e-12 {
                let f = t[r][col];
                for j in 0..cols {
                    t[r][j] -= f * t[row][j];
                }
            }
        }
        basis[row] = col;
    }

    let run_simplex = |t: &mut Vec<Vec<f64>>, basis: &mut Vec<usize>, active_cols: usize| -> bool {
        // Returns false on unbounded.
        loop {
            // Bland: entering = smallest index with negative reduced cost.
            let m = basis.len();
            let mut enter = None;
            for j in 0..active_cols {
                if t[m][j] < -1e-9 {
                    enter = Some(j);
                    break;
                }
            }
            let Some(col) = enter else { return true };
            // Ratio test (Bland: smallest basis index tie-break).
            let mut leave: Option<(usize, f64)> = None;
            for r in 0..m {
                if t[r][col] > 1e-9 {
                    let ratio = t[r][rhs_col] / t[r][col];
                    match leave {
                        None => leave = Some((r, ratio)),
                        Some((br, bratio)) => {
                            if ratio < bratio - 1e-12
                                || ((ratio - bratio).abs() <= 1e-12 && basis[r] < basis[br])
                            {
                                leave = Some((r, ratio));
                            }
                        }
                    }
                }
            }
            let Some((row, _)) = leave else { return false };
            pivot(t, basis, row, col, cols);
        }
    };

    // Phase 1.
    if !run_simplex(&mut t, &mut basis, n + n_slack + n_art) {
        return LpResult::Unbounded; // cannot happen in phase 1, defensive
    }
    if t[m][rhs_col].abs() > 1e-7 {
        // Artificials still in the objective -> infeasible. Note t[m][rhs]
        // is -(sum of artificials).
        return LpResult::Infeasible;
    }
    // Drive any artificial still in the basis out (degenerate).
    for r in 0..m {
        if basis[r] >= n + n_slack {
            // Find a non-artificial column with nonzero entry to pivot in.
            if let Some(col) = (0..n + n_slack).find(|&j| t[r][j].abs() > 1e-9) {
                pivot(&mut t, &mut basis, r, col, cols);
            }
        }
    }

    // Phase 2: rebuild the objective row from the real costs.
    for j in 0..cols {
        t[m][j] = 0.0;
    }
    for j in 0..n {
        t[m][j] = lp.c[j];
    }
    // Make reduced costs consistent with the basis.
    for r in 0..m {
        let bj = basis[r];
        if bj < n && lp.c[bj].abs() > 1e-15 {
            let f = lp.c[bj];
            for j in 0..cols {
                t[m][j] -= f * t[r][j];
            }
        }
    }
    if !run_simplex(&mut t, &mut basis, n + n_slack) {
        return LpResult::Unbounded;
    }

    let mut x = vec![0.0f64; n];
    for r in 0..m {
        if basis[r] < n {
            x[basis[r]] = t[r][rhs_col];
        }
    }
    let obj = x.iter().zip(&lp.c).map(|(xi, ci)| xi * ci).sum();
    LpResult::Optimal { x, obj }
}

// ---------------------------------------------------------------------------
// LP relaxation of the MCKP
// ---------------------------------------------------------------------------

fn relaxation(prob: &DeployProblem, fixed: &[Option<usize>]) -> Lp {
    // Variables: one per (layer, choice) of the *unfixed* layers; fixed
    // layers contribute constants moved to the RHS.
    let mut var_of: Vec<Vec<Option<usize>>> = Vec::new();
    let mut n = 0usize;
    let mut c = Vec::new();
    let mut fixed_cost = 0.0;
    let mut fixed_lat = 0.0;
    for (i, choices) in prob.layers.iter().enumerate() {
        let mut row = vec![None; choices.len()];
        match fixed[i] {
            Some(j) => {
                fixed_cost += choices[j].cost;
                fixed_lat += choices[j].latency;
            }
            None => {
                for (j, ch) in choices.iter().enumerate() {
                    row[j] = Some(n);
                    c.push(ch.cost);
                    n += 1;
                }
            }
        }
        var_of.push(row);
    }
    let _ = fixed_cost;
    let mut a_eq = Vec::new();
    let mut b_eq = Vec::new();
    for (i, choices) in prob.layers.iter().enumerate() {
        if fixed[i].is_some() {
            continue;
        }
        let mut row = vec![0.0; n];
        for j in 0..choices.len() {
            if let Some(v) = var_of[i][j] {
                row[v] = 1.0;
            }
        }
        a_eq.push(row);
        b_eq.push(1.0);
    }
    let mut lat_row = vec![0.0; n];
    for (i, choices) in prob.layers.iter().enumerate() {
        for (j, ch) in choices.iter().enumerate() {
            if let Some(v) = var_of[i][j] {
                lat_row[v] = ch.latency;
            }
        }
    }
    Lp {
        n,
        c,
        a_eq,
        b_eq,
        a_ub: vec![lat_row],
        b_ub: vec![prob.latency_budget - fixed_lat],
    }
}

// ---------------------------------------------------------------------------
// Branch and bound
// ---------------------------------------------------------------------------

/// Solver statistics (for Table IV timing/quality reporting).
#[derive(Clone, Copy, Debug, Default)]
pub struct BbStats {
    pub nodes: u64,
    pub lp_solves: u64,
}

/// Admissible lower bound on the total FIFO cost given the layers fixed
/// so far: per boundary, the exact term when both endpoints are fixed,
/// otherwise the minimum over every still-allowed producer/consumer
/// pair. Never overestimates, so B&B pruning with it stays exact.
fn fifo_lower_bound(prob: &DeployProblem, fixed: &[Option<usize>]) -> f64 {
    let Some(f) = &prob.fifo else { return 0.0 };
    let mut lb = 0.0;
    for k in 0..prob.layers.len().saturating_sub(1) {
        let prods: Vec<usize> = match fixed[k] {
            Some(j) => vec![j],
            None => (0..prob.layers[k].len()).collect(),
        };
        let cons: Vec<usize> = match fixed[k + 1] {
            Some(j) => vec![j],
            None => (0..prob.layers[k + 1].len()).collect(),
        };
        let mut best = f64::INFINITY;
        for &jp in &prods {
            for &jc in &cons {
                let c = f.boundary_cost(k, &prob.layers[k][jp], &prob.layers[k + 1][jc]);
                if c < best {
                    best = c;
                }
            }
        }
        lb += best;
    }
    lb
}

/// Exact MCKP solve by LP-based branch & bound over the dominance-pruned
/// problem (pruning is skipped when a FIFO model couples adjacent
/// layers; the LP bound then gains an admissible per-boundary constant).
/// Returns None if no assignment satisfies the budget.
pub fn solve_bb(prob: &DeployProblem) -> Option<(Solution, BbStats)> {
    let (pruned, maps) = prob.prune_for_solve();
    if pruned.min_latency() > pruned.latency_budget + 1e-9 {
        return None;
    }
    // Incumbent: per-layer minimum-latency choice (always feasible here).
    let greedy: Vec<usize> = pruned
        .layers
        .iter()
        .map(|l| {
            l.iter()
                .enumerate()
                .min_by(|a, b| a.1.latency.partial_cmp(&b.1.latency).unwrap())
                .map(|(j, _)| j)
                .unwrap()
        })
        .collect();
    let mut best = pruned.evaluate(&greedy);
    let mut stats = BbStats::default();
    // Per-layer minimum latencies, memoized once per solve: the branch
    // feasibility pre-check below runs at every node and used to rescan
    // every choice list (O(layers x choices) per branch).
    let min_lat: Vec<f64> = pruned
        .layers
        .iter()
        .map(|l| l.iter().map(|c| c.latency).fold(f64::INFINITY, f64::min))
        .collect();

    fn var_values(
        pruned: &DeployProblem,
        fixed: &[Option<usize>],
        x: &[f64],
    ) -> Vec<Vec<f64>> {
        let mut vals = Vec::with_capacity(pruned.layers.len());
        let mut v = 0usize;
        for (i, choices) in pruned.layers.iter().enumerate() {
            let mut row = vec![0.0; choices.len()];
            if fixed[i].is_none() {
                for slot in row.iter_mut() {
                    *slot = x[v];
                    v += 1;
                }
            } else if let Some(j) = fixed[i] {
                row[j] = 1.0;
            }
            vals.push(row);
        }
        vals
    }

    fn bb(
        pruned: &DeployProblem,
        min_lat: &[f64],
        fixed: &mut Vec<Option<usize>>,
        best: &mut Solution,
        stats: &mut BbStats,
    ) {
        stats.nodes += 1;
        let lp = relaxation(pruned, fixed);
        stats.lp_solves += 1;
        let (x, bound) = match solve_lp(&lp) {
            LpResult::Optimal { x, obj } => {
                let fixed_cost: f64 = fixed
                    .iter()
                    .enumerate()
                    .filter_map(|(i, f)| f.map(|j| pruned.layers[i][j].cost))
                    .sum();
                // The LP sees only the separable cost; the incumbent's
                // cost includes the pairwise FIFO terms, so the bound
                // must carry an admissible FIFO floor to stay exact.
                (x, obj + fixed_cost + fifo_lower_bound(pruned, fixed))
            }
            LpResult::Infeasible => return,
            LpResult::Unbounded => return,
        };
        if bound >= best.cost - 1e-9 {
            return; // prune
        }
        let vals = var_values(pruned, fixed, &x);
        // Find the most fractional layer.
        let mut frac_layer: Option<(usize, f64)> = None;
        for (i, row) in vals.iter().enumerate() {
            if fixed[i].is_some() {
                continue;
            }
            let maxv = row.iter().cloned().fold(0.0, f64::max);
            let fracness = (maxv - 1.0).abs();
            if maxv < 1.0 - 1e-6 && frac_layer.map_or(true, |(_, f)| fracness > f) {
                frac_layer = Some((i, fracness));
            }
        }
        match frac_layer {
            None => {
                // Integral LP solution: extract assignment.
                let mut pick = vec![0usize; pruned.layers.len()];
                for (i, row) in vals.iter().enumerate() {
                    pick[i] = row
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(j, _)| j)
                        .unwrap();
                }
                let sol = pruned.evaluate(&pick);
                if sol.latency <= pruned.latency_budget + 1e-6 && sol.cost < best.cost {
                    *best = sol;
                }
            }
            Some((i, _)) => {
                // Branch: try choices in decreasing LP weight.
                let mut order: Vec<usize> = (0..pruned.layers[i].len()).collect();
                order.sort_by(|&a, &b| vals[i][b].partial_cmp(&vals[i][a]).unwrap());
                for j in order {
                    fixed[i] = Some(j);
                    // Feasibility pre-check on min-latency completion
                    // (per-layer minima come from the memoized table).
                    let lat_fixed: f64 = fixed
                        .iter()
                        .enumerate()
                        .filter_map(|(k, f)| f.map(|jj| pruned.layers[k][jj].latency))
                        .sum();
                    let lat_min_rest: f64 = min_lat
                        .iter()
                        .enumerate()
                        .filter(|(k, _)| fixed[*k].is_none())
                        .map(|(_, &m)| m)
                        .sum();
                    if lat_fixed + lat_min_rest <= pruned.latency_budget + 1e-9 {
                        bb(pruned, min_lat, fixed, best, stats);
                    }
                    fixed[i] = None;
                }
            }
        }
    }

    let mut fixed: Vec<Option<usize>> = vec![None; pruned.layers.len()];
    bb(&pruned, &min_lat, &mut fixed, &mut best, &mut stats);

    // Map picks back to original indices.
    let pick: Vec<usize> = best
        .pick
        .iter()
        .enumerate()
        .map(|(i, &j)| maps[i][j])
        .collect();
    let sol = prob.evaluate(&pick);
    Some((sol, stats))
}

// ---------------------------------------------------------------------------
// DP oracle
// ---------------------------------------------------------------------------

/// Exact solve by dynamic programming over the (integerized) latency
/// budget. Independent oracle for `solve_bb` in tests and benches.
/// With a FIFO model attached the state gains the last layer's choice
/// so the pairwise boundary cost is charged exactly.
pub fn solve_dp(prob: &DeployProblem) -> Option<Solution> {
    if prob.fifo.is_some() {
        return solve_dp_fifo(prob);
    }
    let budget = prob.latency_budget.floor() as i64;
    if budget < 0 {
        return None;
    }
    // Scale latencies to integers (they are cycle counts already).
    let lat = |c: &Choice| c.latency.ceil() as i64;
    let b = budget as usize;
    const INF: f64 = f64::INFINITY;
    // dp[l] = min cost to reach exactly <= l latency after processed layers
    let mut dp = vec![INF; b + 1];
    let mut back: Vec<HashMap<usize, usize>> = Vec::new(); // per layer: l -> choice
    dp[0] = 0.0;
    // To reconstruct we store the chosen option per (layer, latency).
    let mut traces: Vec<Vec<i32>> = Vec::new();
    for choices in &prob.layers {
        let mut ndp = vec![INF; b + 1];
        let mut trace = vec![-1i32; b + 1];
        for l in 0..=b {
            if dp[l] == INF {
                continue;
            }
            for (j, ch) in choices.iter().enumerate() {
                let nl = l as i64 + lat(ch);
                if nl <= budget {
                    let nl = nl as usize;
                    let nc = dp[l] + ch.cost;
                    if nc < ndp[nl] {
                        ndp[nl] = nc;
                        trace[nl] = j as i32;
                    }
                }
            }
        }
        dp = ndp;
        traces.push(trace);
        back.push(HashMap::new());
    }
    // Find the best end state.
    let mut best_l = None;
    let mut best_c = INF;
    for l in 0..=b {
        if dp[l] < best_c {
            best_c = dp[l];
            best_l = Some(l);
        }
    }
    let mut l = best_l?;
    // Reconstruct backwards.
    let mut pick = vec![0usize; prob.layers.len()];
    for i in (0..prob.layers.len()).rev() {
        let j = traces[i][l];
        debug_assert!(j >= 0);
        pick[i] = j as usize;
        l -= lat(&prob.layers[i][j as usize]) as usize;
    }
    Some(prob.evaluate(&pick))
}

/// FIFO-aware DP: state is (layer, integer latency, last choice). The
/// extra choice axis is what makes the pairwise boundary cost Markov —
/// dp[j][l] is the cheapest way to finish layer i with choice j at
/// total latency l, boundary terms up to i included.
fn solve_dp_fifo(prob: &DeployProblem) -> Option<Solution> {
    let budget = prob.latency_budget.floor() as i64;
    if budget < 0 {
        return None;
    }
    let n = prob.layers.len();
    if n == 0 {
        return Some(prob.evaluate(&[]));
    }
    let f = prob.fifo.as_ref().unwrap();
    let lat = |c: &Choice| c.latency.ceil() as i64;
    let b = budget as usize;
    const INF: f64 = f64::INFINITY;
    let mut dp: Vec<Vec<f64>> = vec![vec![INF; b + 1]; prob.layers[0].len()];
    for (j, ch) in prob.layers[0].iter().enumerate() {
        let l = lat(ch);
        if (0..=budget).contains(&l) && ch.cost < dp[j][l as usize] {
            dp[j][l as usize] = ch.cost;
        }
    }
    // traces[i-1][j][l] = producer choice jp that reached (layer i, j, l).
    let mut traces: Vec<Vec<Vec<i32>>> = Vec::with_capacity(n - 1);
    for i in 1..n {
        let mut ndp: Vec<Vec<f64>> = vec![vec![INF; b + 1]; prob.layers[i].len()];
        let mut trace: Vec<Vec<i32>> = vec![vec![-1i32; b + 1]; prob.layers[i].len()];
        for (jp, row) in dp.iter().enumerate() {
            for (l, &c) in row.iter().enumerate() {
                if c == INF {
                    continue;
                }
                for (j, ch) in prob.layers[i].iter().enumerate() {
                    let nl = l as i64 + lat(ch);
                    if nl <= budget {
                        let nl = nl as usize;
                        let nc = c
                            + ch.cost
                            + f.boundary_cost(i - 1, &prob.layers[i - 1][jp], ch);
                        if nc < ndp[j][nl] {
                            ndp[j][nl] = nc;
                            trace[j][nl] = jp as i32;
                        }
                    }
                }
            }
        }
        dp = ndp;
        traces.push(trace);
    }
    let mut best: Option<(usize, usize)> = None;
    let mut best_c = INF;
    for (j, row) in dp.iter().enumerate() {
        for (l, &c) in row.iter().enumerate() {
            if c < best_c {
                best_c = c;
                best = Some((j, l));
            }
        }
    }
    let (mut j, mut l) = best?;
    let mut pick = vec![0usize; n];
    pick[n - 1] = j;
    for i in (1..n).rev() {
        let jp = traces[i - 1][j][l];
        debug_assert!(jp >= 0);
        l -= lat(&prob.layers[i][j]) as usize;
        j = jp as usize;
        pick[i - 1] = j;
    }
    Some(prob.evaluate(&pick))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testkit::prop_check;

    fn ch(reuse: usize, cost: f64, latency: f64) -> Choice {
        Choice { reuse, cost, latency }
    }

    fn random_problem(rng: &mut Rng, n_layers: usize, n_choices: usize) -> DeployProblem {
        let layers: Vec<Vec<Choice>> = (0..n_layers)
            .map(|_| {
                (0..n_choices)
                    .map(|j| {
                        // Correlated like the real trade-off: higher reuse,
                        // lower cost, higher latency + noise.
                        let r = 1usize << j;
                        let cost = 1000.0 / (j + 1) as f64 + rng.range_f64(0.0, 50.0);
                        let lat = (10 * (j + 1)) as f64 + rng.range_f64(0.0, 5.0).floor();
                        ch(r, cost, lat)
                    })
                    .collect()
            })
            .collect();
        let min_lat: f64 = layers
            .iter()
            .map(|l| l.iter().map(|c| c.latency).fold(f64::INFINITY, f64::min))
            .sum();
        let max_lat: f64 = layers
            .iter()
            .map(|l| l.iter().map(|c| c.latency).fold(0.0, f64::max))
            .sum();
        let budget = rng.range_f64(min_lat, max_lat).floor();
        DeployProblem { layers, latency_budget: budget, fifo: None }
    }

    fn random_fifo_problem(
        rng: &mut Rng,
        n_layers: usize,
        n_choices: usize,
    ) -> DeployProblem {
        let prob = random_problem(rng, n_layers, n_choices);
        let widths: Vec<f64> = (1..n_layers)
            .map(|_| rng.range_f64(1.0, 64.0).floor())
            .collect();
        prob.with_fifo(FifoModel {
            cost_per_slot: rng.range_f64(0.5, 8.0),
            min_depth: 2.0,
            widths,
        })
    }

    /// Exhaustive oracle for small instances — the ground truth the
    /// FIFO-aware solvers are checked against.
    fn brute_force(prob: &DeployProblem) -> Option<Solution> {
        let n = prob.layers.len();
        let mut pick = vec![0usize; n];
        let mut best: Option<Solution> = None;
        loop {
            let sol = prob.evaluate(&pick);
            if sol.latency <= prob.latency_budget + 1e-9
                && best.as_ref().map_or(true, |b| sol.cost < b.cost)
            {
                best = Some(sol);
            }
            let mut i = 0;
            loop {
                if i == n {
                    return best;
                }
                pick[i] += 1;
                if pick[i] < prob.layers[i].len() {
                    break;
                }
                pick[i] = 0;
                i += 1;
            }
        }
    }

    #[test]
    fn lp_simple_known_solution() {
        // min -x - y, x + y <= 1 -> obj -1 on the segment; with x,y >= 0.
        let lp = Lp {
            n: 2,
            c: vec![-1.0, -1.0],
            a_eq: vec![],
            b_eq: vec![],
            a_ub: vec![vec![1.0, 1.0]],
            b_ub: vec![1.0],
        };
        match solve_lp(&lp) {
            LpResult::Optimal { obj, x } => {
                assert!((obj + 1.0).abs() < 1e-9);
                assert!((x[0] + x[1] - 1.0).abs() < 1e-9);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn lp_equality_constraint() {
        // min x + 2y s.t. x + y = 1 -> x=1, y=0, obj 1.
        let lp = Lp {
            n: 2,
            c: vec![1.0, 2.0],
            a_eq: vec![vec![1.0, 1.0]],
            b_eq: vec![1.0],
            a_ub: vec![],
            b_ub: vec![],
        };
        match solve_lp(&lp) {
            LpResult::Optimal { obj, x } => {
                assert!((obj - 1.0).abs() < 1e-9);
                assert!((x[0] - 1.0).abs() < 1e-9);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn lp_detects_infeasible() {
        // x <= -1 with x >= 0.
        let lp = Lp {
            n: 1,
            c: vec![1.0],
            a_eq: vec![],
            b_eq: vec![],
            a_ub: vec![vec![1.0], vec![-1.0]],
            b_ub: vec![-1.0, -2.0], // x <= -1 and x >= 2: infeasible
        };
        assert_eq!(solve_lp(&lp), LpResult::Infeasible);
    }

    #[test]
    fn lp_detects_unbounded() {
        // min -x with no constraints.
        let lp = Lp {
            n: 1,
            c: vec![-1.0],
            a_eq: vec![],
            b_eq: vec![],
            a_ub: vec![],
            b_ub: vec![],
        };
        assert_eq!(solve_lp(&lp), LpResult::Unbounded);
    }

    #[test]
    fn bb_solves_tiny_instance_exactly() {
        // Two layers, clear optimum under budget 20:
        let prob = DeployProblem {
            layers: vec![
                vec![ch(1, 100.0, 5.0), ch(2, 60.0, 10.0), ch(4, 30.0, 20.0)],
                vec![ch(1, 80.0, 5.0), ch(2, 50.0, 10.0)],
            ],
            latency_budget: 20.0,
            fifo: None,
        };
        let (sol, _) = solve_bb(&prob).unwrap();
        // Best: layer0 j=1 (60, 10) + layer1 j=1 (50, 10) = 110 @ 20.
        assert_eq!(sol.cost, 110.0);
        assert_eq!(sol.latency, 20.0);
        assert_eq!(solve_dp(&prob).unwrap().cost, 110.0);
    }

    #[test]
    fn bb_infeasible_when_budget_too_tight() {
        let prob = DeployProblem {
            layers: vec![vec![ch(1, 1.0, 100.0)]],
            latency_budget: 50.0,
            fifo: None,
        };
        assert!(solve_bb(&prob).is_none());
        assert!(solve_dp(&prob).is_none());
    }

    #[test]
    fn prune_keeps_pareto_choices_only() {
        let prob = DeployProblem {
            layers: vec![vec![
                ch(1, 100.0, 10.0),
                ch(2, 120.0, 12.0), // dominated (worse both ways)
                ch(4, 50.0, 20.0),
                ch(8, 50.0, 30.0), // dominated (same cost, more latency)
            ]],
            latency_budget: 100.0,
            fifo: None,
        };
        let (pruned, maps) = prob.prune_dominated();
        assert_eq!(pruned.layers[0].len(), 2);
        assert_eq!(maps[0], vec![0, 2]);
    }

    #[test]
    fn property_bb_matches_dp_oracle() {
        prop_check("bb-equals-dp", 40, |g| {
            let mut rng = Rng::new(g.rng.next_u64());
            let n_layers = g.int(1, 6);
            let n_choices = g.int(2, 6);
            let prob = random_problem(&mut rng, n_layers, n_choices);
            let bb = solve_bb(&prob);
            let dp = solve_dp(&prob);
            match (bb, dp) {
                (None, None) => Ok(()),
                (Some((b, _)), Some(d)) => {
                    if (b.cost - d.cost).abs() < 1e-6 {
                        Ok(())
                    } else {
                        Err(format!(
                            "bb cost {} != dp cost {} (budget {})",
                            b.cost, d.cost, prob.latency_budget
                        ))
                    }
                }
                (b, d) => Err(format!(
                    "feasibility disagreement: bb {:?} dp {:?}",
                    b.map(|x| x.0.cost),
                    d.map(|x| x.cost)
                )),
            }
        });
    }

    #[test]
    fn property_solutions_respect_budget() {
        prop_check("solutions-within-budget", 30, |g| {
            let mut rng = Rng::new(g.rng.next_u64());
            let prob = random_problem(&mut rng, g.int(1, 8), g.int(2, 8));
            if let Some((sol, _)) = solve_bb(&prob) {
                if sol.latency > prob.latency_budget + 1e-6 {
                    return Err(format!(
                        "bb latency {} exceeds budget {}",
                        sol.latency, prob.latency_budget
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn with_budget_rebudgets_without_touching_choices() {
        let mut rng = Rng::new(0xB4D6);
        let prob = random_problem(&mut rng, 3, 4);
        let re = prob.with_budget(123.0);
        assert_eq!(re.latency_budget, 123.0);
        assert_eq!(re.layers, prob.layers);
        // Solving the re-budgeted copy is exactly a solve at that budget.
        let mut direct = prob.clone();
        direct.latency_budget = 123.0;
        assert_eq!(
            solve_bb(&re).map(|(s, _)| s),
            solve_bb(&direct).map(|(s, _)| s)
        );
    }

    #[test]
    fn permutation_count() {
        let prob = DeployProblem {
            layers: vec![
                vec![ch(1, 0.0, 0.0); 10],
                vec![ch(1, 0.0, 0.0); 20],
                vec![ch(1, 0.0, 0.0); 3],
            ],
            latency_budget: 1.0,
            fifo: None,
        };
        assert_eq!(prob.permutations(), 600.0);
    }

    #[test]
    fn fifo_boundary_cost_charges_the_rate_mismatch() {
        let f = FifoModel { cost_per_slot: 2.0, min_depth: 3.0, widths: vec![10.0] };
        // Producer reuse 2, consumer reuse 8: producer is 4x faster,
        // mismatch 1 - 2/8 = 0.75 -> depth 3 + 10*0.75 = 10.5.
        let fast = ch(2, 0.0, 0.0);
        let slow = ch(8, 0.0, 0.0);
        assert!((f.boundary_cost(0, &fast, &slow) - 21.0).abs() < 1e-12);
        // Consumer faster (or matched): only the skid depth.
        assert!((f.boundary_cost(0, &slow, &fast) - 6.0).abs() < 1e-12);
        assert!((f.boundary_cost(0, &fast, &fast) - 6.0).abs() < 1e-12);
    }

    #[test]
    fn fifo_evaluate_adds_boundary_terms_to_the_separable_cost() {
        let base = DeployProblem {
            layers: vec![
                vec![ch(1, 10.0, 5.0), ch(4, 6.0, 9.0)],
                vec![ch(2, 7.0, 4.0)],
            ],
            latency_budget: 20.0,
            fifo: None,
        };
        let sep = base.evaluate(&[0, 0]);
        let prob = base.with_fifo(FifoModel::uniform(2, 1.0, 0.0));
        let sol = prob.evaluate(&[0, 0]);
        // reuse 1 -> 2: mismatch 1 - 1/2 = 0.5.
        assert!((sol.cost - (sep.cost + 0.5)).abs() < 1e-12);
        assert_eq!(sol.latency, sep.latency, "FIFO cost never touches latency");
        // reuse 4 -> 2: consumer faster, zero extra on a min_depth=0 model.
        assert_eq!(prob.evaluate(&[1, 0]).cost, base.evaluate(&[1, 0]).cost);
    }

    #[test]
    fn property_fifo_solvers_match_brute_force() {
        prop_check("fifo-bb-dp-equal-brute-force", 40, |g| {
            let mut rng = Rng::new(g.rng.next_u64());
            let n_layers = g.int(1, 4);
            let n_choices = g.int(2, 4);
            let prob = random_fifo_problem(&mut rng, n_layers, n_choices);
            let oracle = brute_force(&prob);
            let bb = solve_bb(&prob).map(|(s, _)| s);
            let dp = solve_dp(&prob);
            for (name, got) in [("bb", &bb), ("dp", &dp)] {
                match (&oracle, got) {
                    (None, None) => {}
                    (Some(o), Some(s)) => {
                        if (o.cost - s.cost).abs() > 1e-6 {
                            return Err(format!(
                                "{name} cost {} != brute-force {} (budget {})",
                                s.cost, o.cost, prob.latency_budget
                            ));
                        }
                        if s.latency > prob.latency_budget + 1e-9 {
                            return Err(format!("{name} violates the budget"));
                        }
                    }
                    (o, s) => {
                        return Err(format!(
                            "{name} feasibility disagreement: oracle {:?} got {:?}",
                            o.as_ref().map(|x| x.cost),
                            s.as_ref().map(|x| x.cost)
                        ))
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fifo_changes_the_optimum_when_buffers_are_expensive() {
        // Separable optimum pairs a fast producer with a slow consumer;
        // a pricey FIFO model flips it to the rate-matched pair.
        let base = DeployProblem {
            layers: vec![
                vec![ch(1, 10.0, 5.0), ch(8, 11.0, 5.0)],
                vec![ch(8, 10.0, 5.0)],
            ],
            latency_budget: 20.0,
            fifo: None,
        };
        let (sep, _) = solve_bb(&base).unwrap();
        assert_eq!(sep.pick, vec![0, 0], "separable: cheaper fast producer wins");
        let priced = base.with_fifo(FifoModel {
            cost_per_slot: 4.0,
            min_depth: 0.0,
            widths: vec![1.0],
        });
        let (sol, _) = solve_bb(&priced).unwrap();
        // Pair (1,8): mismatch 7/8 -> +3.5 on cost 20; pair (8,8): +0 on 21.
        assert_eq!(sol.pick, vec![1, 0], "FIFO pricing flips to the matched pair");
        assert_eq!(solve_dp(&priced).unwrap().cost, sol.cost);
    }

    #[test]
    fn bb_on_realistic_scale_fast() {
        // ~11 layers x ~40 choices: must solve in well under a second.
        let mut rng = Rng::new(77);
        let prob = random_problem(&mut rng, 11, 40);
        let t0 = std::time::Instant::now();
        let sol = solve_bb(&prob);
        assert!(sol.is_some());
        // Debug builds are ~20x slower than release; the perf bench
        // (perf_hotpaths) tracks the release-mode number (~0.1 s).
        assert!(t0.elapsed().as_secs_f64() < 20.0, "{:?}", t0.elapsed());
    }
}
