//! Mixed-integer reuse-factor optimizer (Gurobi substitute — paper §IV-B).
//!
//! The deployment problem: for each layer i pick one reuse factor
//! R_i (a divisor of n_in·n_out), minimizing the summed predicted resource
//! cost (LUT+FF+BRAM+DSP) subject to the summed predicted latency staying
//! within the real-time budget (50,000 cycles = 200 µs at 250 MHz).
//!
//! With every feature fixed except the reuse factor, the random-forest
//! models collapse to per-(layer, R) constants (paper §IV-B), so the MIP is
//! exactly a **multiple-choice knapsack**: binary x_{i,j}, Σ_j x_{i,j} = 1,
//! min Σ c_{i,j} x_{i,j} s.t. Σ l_{i,j} x_{i,j} ≤ L.
//!
//! Two exact solvers are provided and cross-checked in the tests:
//!
//! * [`solve_bb`] — the Gurobi-shaped path: LP relaxation by a two-phase
//!   dense **simplex**, branch-and-bound on the most fractional layer,
//!   dominance pruning. This is what `N-TORC` timing claims run on.
//! * [`solve_dp`] — dynamic programming over the integer latency budget;
//!   slower but an independent oracle for the optimum.

use std::collections::HashMap;

/// One reuse-factor option for a layer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Choice {
    pub reuse: usize,
    pub cost: f64,
    pub latency: f64,
}

/// A deployment instance.
#[derive(Clone, Debug)]
pub struct DeployProblem {
    /// Per-layer candidate choices (non-empty).
    pub layers: Vec<Vec<Choice>>,
    /// Total latency budget in cycles.
    pub latency_budget: f64,
}

/// A reuse-factor assignment.
#[derive(Clone, Debug, PartialEq)]
pub struct Solution {
    /// Index into `layers[i]` for each layer.
    pub pick: Vec<usize>,
    pub cost: f64,
    pub latency: f64,
}

impl DeployProblem {
    /// Total number of assignments (the paper's "RF permutations").
    pub fn permutations(&self) -> f64 {
        self.layers.iter().map(|l| l.len() as f64).product()
    }

    pub fn evaluate(&self, pick: &[usize]) -> Solution {
        assert_eq!(pick.len(), self.layers.len());
        let mut cost = 0.0;
        let mut latency = 0.0;
        for (i, &j) in pick.iter().enumerate() {
            cost += self.layers[i][j].cost;
            latency += self.layers[i][j].latency;
        }
        Solution { pick: pick.to_vec(), cost, latency }
    }

    pub fn is_feasible(&self, pick: &[usize]) -> bool {
        self.evaluate(pick).latency <= self.latency_budget + 1e-9
    }

    /// The same instance re-budgeted — the shape every per-budget
    /// re-solve (cross-checks, the [`crate::solver`] registry) takes,
    /// instead of a clone-then-mutate at each call site.
    pub fn with_budget(&self, latency_budget: f64) -> DeployProblem {
        DeployProblem { layers: self.layers.clone(), latency_budget }
    }

    /// Remove dominated choices per layer (another choice has <= latency
    /// and <= cost, one strict). Returns the pruned problem and, per
    /// layer, the original index of each surviving choice.
    pub fn prune_dominated(&self) -> (DeployProblem, Vec<Vec<usize>>) {
        let mut layers = Vec::with_capacity(self.layers.len());
        let mut maps = Vec::with_capacity(self.layers.len());
        for choices in &self.layers {
            let mut order: Vec<usize> = (0..choices.len()).collect();
            // Sort by latency asc, then cost asc.
            order.sort_by(|&a, &b| {
                choices[a]
                    .latency
                    .partial_cmp(&choices[b].latency)
                    .unwrap()
                    .then(choices[a].cost.partial_cmp(&choices[b].cost).unwrap())
            });
            let mut kept: Vec<usize> = Vec::new();
            let mut best_cost = f64::INFINITY;
            for &j in &order {
                if choices[j].cost < best_cost - 1e-12 {
                    kept.push(j);
                    best_cost = choices[j].cost;
                }
            }
            maps.push(kept.clone());
            layers.push(kept.iter().map(|&j| choices[j]).collect());
        }
        (
            DeployProblem { layers, latency_budget: self.latency_budget },
            maps,
        )
    }

    /// Quick feasibility check: even the min-latency assignment must fit.
    pub fn min_latency(&self) -> f64 {
        self.layers
            .iter()
            .map(|l| l.iter().map(|c| c.latency).fold(f64::INFINITY, f64::min))
            .sum()
    }
}

// ---------------------------------------------------------------------------
// Two-phase dense simplex (min c.x, A_eq x = b_eq, A_ub x <= b_ub, x >= 0)
// ---------------------------------------------------------------------------

/// LP in standard inequality/equality form.
#[derive(Clone, Debug, Default)]
pub struct Lp {
    pub n: usize,
    pub c: Vec<f64>,
    pub a_eq: Vec<Vec<f64>>,
    pub b_eq: Vec<f64>,
    pub a_ub: Vec<Vec<f64>>,
    pub b_ub: Vec<f64>,
}

/// LP outcome.
#[derive(Clone, Debug, PartialEq)]
pub enum LpResult {
    Optimal { x: Vec<f64>, obj: f64 },
    Infeasible,
    Unbounded,
}

/// Two-phase primal simplex with Bland's rule (anti-cycling). Dense
/// tableau; sized for the MCKP relaxations this crate generates
/// (hundreds of columns, tens of rows).
pub fn solve_lp(lp: &Lp) -> LpResult {
    let n = lp.n;
    let m_ub = lp.a_ub.len();
    let m_eq = lp.a_eq.len();
    let m = m_ub + m_eq;
    // Columns: n structural + m_ub slack + m artificial; rows: m + 1 (obj).
    let n_slack = m_ub;
    let n_art = m;
    let cols = n + n_slack + n_art + 1; // + RHS
    let rhs_col = cols - 1;
    let mut t = vec![vec![0.0f64; cols]; m + 1];
    let mut basis = vec![0usize; m];

    // Fill rows: first the ub rows, then the eq rows; make RHS >= 0.
    for (r, (row, &b)) in lp.a_ub.iter().zip(&lp.b_ub).enumerate() {
        let sign = if b < 0.0 { -1.0 } else { 1.0 };
        for j in 0..n {
            t[r][j] = sign * row[j];
        }
        t[r][n + r] = sign; // slack (may flip to surplus with sign)
        t[r][rhs_col] = sign * b;
    }
    for (k, (row, &b)) in lp.a_eq.iter().zip(&lp.b_eq).enumerate() {
        let r = m_ub + k;
        let sign = if b < 0.0 { -1.0 } else { 1.0 };
        for j in 0..n {
            t[r][j] = sign * row[j];
        }
        t[r][rhs_col] = sign * b;
    }
    // Artificials on every row for a uniform phase-1 start.
    for r in 0..m {
        t[r][n + n_slack + r] = 1.0;
        basis[r] = n + n_slack + r;
    }

    // Phase 1 objective: minimize the sum of artificials. Reduced cost of
    // column j is c_j - z_j; the artificials are basic with cost 1, so
    // their reduced costs are 0 and every other column gets -(sum of its
    // constraint coefficients).
    for j in 0..cols {
        if (n + n_slack..n + n_slack + n_art).contains(&j) {
            t[m][j] = 0.0;
            continue;
        }
        let mut s = 0.0;
        for r in 0..m {
            s += t[r][j];
        }
        t[m][j] = -s;
    }

    fn pivot(t: &mut [Vec<f64>], basis: &mut [usize], row: usize, col: usize, cols: usize) {
        let m = basis.len();
        let p = t[row][col];
        for j in 0..cols {
            t[row][j] /= p;
        }
        for r in 0..=m {
            if r != row && t[r][col].abs() > 1e-12 {
                let f = t[r][col];
                for j in 0..cols {
                    t[r][j] -= f * t[row][j];
                }
            }
        }
        basis[row] = col;
    }

    let run_simplex = |t: &mut Vec<Vec<f64>>, basis: &mut Vec<usize>, active_cols: usize| -> bool {
        // Returns false on unbounded.
        loop {
            // Bland: entering = smallest index with negative reduced cost.
            let m = basis.len();
            let mut enter = None;
            for j in 0..active_cols {
                if t[m][j] < -1e-9 {
                    enter = Some(j);
                    break;
                }
            }
            let Some(col) = enter else { return true };
            // Ratio test (Bland: smallest basis index tie-break).
            let mut leave: Option<(usize, f64)> = None;
            for r in 0..m {
                if t[r][col] > 1e-9 {
                    let ratio = t[r][rhs_col] / t[r][col];
                    match leave {
                        None => leave = Some((r, ratio)),
                        Some((br, bratio)) => {
                            if ratio < bratio - 1e-12
                                || ((ratio - bratio).abs() <= 1e-12 && basis[r] < basis[br])
                            {
                                leave = Some((r, ratio));
                            }
                        }
                    }
                }
            }
            let Some((row, _)) = leave else { return false };
            pivot(t, basis, row, col, cols);
        }
    };

    // Phase 1.
    if !run_simplex(&mut t, &mut basis, n + n_slack + n_art) {
        return LpResult::Unbounded; // cannot happen in phase 1, defensive
    }
    if t[m][rhs_col].abs() > 1e-7 {
        // Artificials still in the objective -> infeasible. Note t[m][rhs]
        // is -(sum of artificials).
        return LpResult::Infeasible;
    }
    // Drive any artificial still in the basis out (degenerate).
    for r in 0..m {
        if basis[r] >= n + n_slack {
            // Find a non-artificial column with nonzero entry to pivot in.
            if let Some(col) = (0..n + n_slack).find(|&j| t[r][j].abs() > 1e-9) {
                pivot(&mut t, &mut basis, r, col, cols);
            }
        }
    }

    // Phase 2: rebuild the objective row from the real costs.
    for j in 0..cols {
        t[m][j] = 0.0;
    }
    for j in 0..n {
        t[m][j] = lp.c[j];
    }
    // Make reduced costs consistent with the basis.
    for r in 0..m {
        let bj = basis[r];
        if bj < n && lp.c[bj].abs() > 1e-15 {
            let f = lp.c[bj];
            for j in 0..cols {
                t[m][j] -= f * t[r][j];
            }
        }
    }
    if !run_simplex(&mut t, &mut basis, n + n_slack) {
        return LpResult::Unbounded;
    }

    let mut x = vec![0.0f64; n];
    for r in 0..m {
        if basis[r] < n {
            x[basis[r]] = t[r][rhs_col];
        }
    }
    let obj = x.iter().zip(&lp.c).map(|(xi, ci)| xi * ci).sum();
    LpResult::Optimal { x, obj }
}

// ---------------------------------------------------------------------------
// LP relaxation of the MCKP
// ---------------------------------------------------------------------------

fn relaxation(prob: &DeployProblem, fixed: &[Option<usize>]) -> Lp {
    // Variables: one per (layer, choice) of the *unfixed* layers; fixed
    // layers contribute constants moved to the RHS.
    let mut var_of: Vec<Vec<Option<usize>>> = Vec::new();
    let mut n = 0usize;
    let mut c = Vec::new();
    let mut fixed_cost = 0.0;
    let mut fixed_lat = 0.0;
    for (i, choices) in prob.layers.iter().enumerate() {
        let mut row = vec![None; choices.len()];
        match fixed[i] {
            Some(j) => {
                fixed_cost += choices[j].cost;
                fixed_lat += choices[j].latency;
            }
            None => {
                for (j, ch) in choices.iter().enumerate() {
                    row[j] = Some(n);
                    c.push(ch.cost);
                    n += 1;
                }
            }
        }
        var_of.push(row);
    }
    let _ = fixed_cost;
    let mut a_eq = Vec::new();
    let mut b_eq = Vec::new();
    for (i, choices) in prob.layers.iter().enumerate() {
        if fixed[i].is_some() {
            continue;
        }
        let mut row = vec![0.0; n];
        for j in 0..choices.len() {
            if let Some(v) = var_of[i][j] {
                row[v] = 1.0;
            }
        }
        a_eq.push(row);
        b_eq.push(1.0);
    }
    let mut lat_row = vec![0.0; n];
    for (i, choices) in prob.layers.iter().enumerate() {
        for (j, ch) in choices.iter().enumerate() {
            if let Some(v) = var_of[i][j] {
                lat_row[v] = ch.latency;
            }
        }
    }
    Lp {
        n,
        c,
        a_eq,
        b_eq,
        a_ub: vec![lat_row],
        b_ub: vec![prob.latency_budget - fixed_lat],
    }
}

// ---------------------------------------------------------------------------
// Branch and bound
// ---------------------------------------------------------------------------

/// Solver statistics (for Table IV timing/quality reporting).
#[derive(Clone, Copy, Debug, Default)]
pub struct BbStats {
    pub nodes: u64,
    pub lp_solves: u64,
}

/// Exact MCKP solve by LP-based branch & bound over the dominance-pruned
/// problem. Returns None if no assignment satisfies the budget.
pub fn solve_bb(prob: &DeployProblem) -> Option<(Solution, BbStats)> {
    let (pruned, maps) = prob.prune_dominated();
    if pruned.min_latency() > pruned.latency_budget + 1e-9 {
        return None;
    }
    // Incumbent: per-layer minimum-latency choice (always feasible here).
    let greedy: Vec<usize> = pruned
        .layers
        .iter()
        .map(|l| {
            l.iter()
                .enumerate()
                .min_by(|a, b| a.1.latency.partial_cmp(&b.1.latency).unwrap())
                .map(|(j, _)| j)
                .unwrap()
        })
        .collect();
    let mut best = pruned.evaluate(&greedy);
    let mut stats = BbStats::default();
    // Per-layer minimum latencies, memoized once per solve: the branch
    // feasibility pre-check below runs at every node and used to rescan
    // every choice list (O(layers x choices) per branch).
    let min_lat: Vec<f64> = pruned
        .layers
        .iter()
        .map(|l| l.iter().map(|c| c.latency).fold(f64::INFINITY, f64::min))
        .collect();

    fn var_values(
        pruned: &DeployProblem,
        fixed: &[Option<usize>],
        x: &[f64],
    ) -> Vec<Vec<f64>> {
        let mut vals = Vec::with_capacity(pruned.layers.len());
        let mut v = 0usize;
        for (i, choices) in pruned.layers.iter().enumerate() {
            let mut row = vec![0.0; choices.len()];
            if fixed[i].is_none() {
                for slot in row.iter_mut() {
                    *slot = x[v];
                    v += 1;
                }
            } else if let Some(j) = fixed[i] {
                row[j] = 1.0;
            }
            vals.push(row);
        }
        vals
    }

    fn bb(
        pruned: &DeployProblem,
        min_lat: &[f64],
        fixed: &mut Vec<Option<usize>>,
        best: &mut Solution,
        stats: &mut BbStats,
    ) {
        stats.nodes += 1;
        let lp = relaxation(pruned, fixed);
        stats.lp_solves += 1;
        let (x, bound) = match solve_lp(&lp) {
            LpResult::Optimal { x, obj } => {
                let fixed_cost: f64 = fixed
                    .iter()
                    .enumerate()
                    .filter_map(|(i, f)| f.map(|j| pruned.layers[i][j].cost))
                    .sum();
                (x, obj + fixed_cost)
            }
            LpResult::Infeasible => return,
            LpResult::Unbounded => return,
        };
        if bound >= best.cost - 1e-9 {
            return; // prune
        }
        let vals = var_values(pruned, fixed, &x);
        // Find the most fractional layer.
        let mut frac_layer: Option<(usize, f64)> = None;
        for (i, row) in vals.iter().enumerate() {
            if fixed[i].is_some() {
                continue;
            }
            let maxv = row.iter().cloned().fold(0.0, f64::max);
            let fracness = (maxv - 1.0).abs();
            if maxv < 1.0 - 1e-6 && frac_layer.map_or(true, |(_, f)| fracness > f) {
                frac_layer = Some((i, fracness));
            }
        }
        match frac_layer {
            None => {
                // Integral LP solution: extract assignment.
                let mut pick = vec![0usize; pruned.layers.len()];
                for (i, row) in vals.iter().enumerate() {
                    pick[i] = row
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(j, _)| j)
                        .unwrap();
                }
                let sol = pruned.evaluate(&pick);
                if sol.latency <= pruned.latency_budget + 1e-6 && sol.cost < best.cost {
                    *best = sol;
                }
            }
            Some((i, _)) => {
                // Branch: try choices in decreasing LP weight.
                let mut order: Vec<usize> = (0..pruned.layers[i].len()).collect();
                order.sort_by(|&a, &b| vals[i][b].partial_cmp(&vals[i][a]).unwrap());
                for j in order {
                    fixed[i] = Some(j);
                    // Feasibility pre-check on min-latency completion
                    // (per-layer minima come from the memoized table).
                    let lat_fixed: f64 = fixed
                        .iter()
                        .enumerate()
                        .filter_map(|(k, f)| f.map(|jj| pruned.layers[k][jj].latency))
                        .sum();
                    let lat_min_rest: f64 = min_lat
                        .iter()
                        .enumerate()
                        .filter(|(k, _)| fixed[*k].is_none())
                        .map(|(_, &m)| m)
                        .sum();
                    if lat_fixed + lat_min_rest <= pruned.latency_budget + 1e-9 {
                        bb(pruned, min_lat, fixed, best, stats);
                    }
                    fixed[i] = None;
                }
            }
        }
    }

    let mut fixed: Vec<Option<usize>> = vec![None; pruned.layers.len()];
    bb(&pruned, &min_lat, &mut fixed, &mut best, &mut stats);

    // Map picks back to original indices.
    let pick: Vec<usize> = best
        .pick
        .iter()
        .enumerate()
        .map(|(i, &j)| maps[i][j])
        .collect();
    let sol = prob.evaluate(&pick);
    Some((sol, stats))
}

// ---------------------------------------------------------------------------
// DP oracle
// ---------------------------------------------------------------------------

/// Exact solve by dynamic programming over the (integerized) latency
/// budget. Independent oracle for `solve_bb` in tests and benches.
pub fn solve_dp(prob: &DeployProblem) -> Option<Solution> {
    let budget = prob.latency_budget.floor() as i64;
    if budget < 0 {
        return None;
    }
    // Scale latencies to integers (they are cycle counts already).
    let lat = |c: &Choice| c.latency.ceil() as i64;
    let b = budget as usize;
    const INF: f64 = f64::INFINITY;
    // dp[l] = min cost to reach exactly <= l latency after processed layers
    let mut dp = vec![INF; b + 1];
    let mut back: Vec<HashMap<usize, usize>> = Vec::new(); // per layer: l -> choice
    dp[0] = 0.0;
    // To reconstruct we store the chosen option per (layer, latency).
    let mut traces: Vec<Vec<i32>> = Vec::new();
    for choices in &prob.layers {
        let mut ndp = vec![INF; b + 1];
        let mut trace = vec![-1i32; b + 1];
        for l in 0..=b {
            if dp[l] == INF {
                continue;
            }
            for (j, ch) in choices.iter().enumerate() {
                let nl = l as i64 + lat(ch);
                if nl <= budget {
                    let nl = nl as usize;
                    let nc = dp[l] + ch.cost;
                    if nc < ndp[nl] {
                        ndp[nl] = nc;
                        trace[nl] = j as i32;
                    }
                }
            }
        }
        dp = ndp;
        traces.push(trace);
        back.push(HashMap::new());
    }
    // Find the best end state.
    let mut best_l = None;
    let mut best_c = INF;
    for l in 0..=b {
        if dp[l] < best_c {
            best_c = dp[l];
            best_l = Some(l);
        }
    }
    let mut l = best_l?;
    // Reconstruct backwards.
    let mut pick = vec![0usize; prob.layers.len()];
    for i in (0..prob.layers.len()).rev() {
        let j = traces[i][l];
        debug_assert!(j >= 0);
        pick[i] = j as usize;
        l -= lat(&prob.layers[i][j as usize]) as usize;
    }
    Some(prob.evaluate(&pick))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testkit::prop_check;

    fn ch(reuse: usize, cost: f64, latency: f64) -> Choice {
        Choice { reuse, cost, latency }
    }

    fn random_problem(rng: &mut Rng, n_layers: usize, n_choices: usize) -> DeployProblem {
        let layers: Vec<Vec<Choice>> = (0..n_layers)
            .map(|_| {
                (0..n_choices)
                    .map(|j| {
                        // Correlated like the real trade-off: higher reuse,
                        // lower cost, higher latency + noise.
                        let r = 1usize << j;
                        let cost = 1000.0 / (j + 1) as f64 + rng.range_f64(0.0, 50.0);
                        let lat = (10 * (j + 1)) as f64 + rng.range_f64(0.0, 5.0).floor();
                        ch(r, cost, lat)
                    })
                    .collect()
            })
            .collect();
        let min_lat: f64 = layers
            .iter()
            .map(|l| l.iter().map(|c| c.latency).fold(f64::INFINITY, f64::min))
            .sum();
        let max_lat: f64 = layers
            .iter()
            .map(|l| l.iter().map(|c| c.latency).fold(0.0, f64::max))
            .sum();
        let budget = rng.range_f64(min_lat, max_lat).floor();
        DeployProblem { layers, latency_budget: budget }
    }

    #[test]
    fn lp_simple_known_solution() {
        // min -x - y, x + y <= 1 -> obj -1 on the segment; with x,y >= 0.
        let lp = Lp {
            n: 2,
            c: vec![-1.0, -1.0],
            a_eq: vec![],
            b_eq: vec![],
            a_ub: vec![vec![1.0, 1.0]],
            b_ub: vec![1.0],
        };
        match solve_lp(&lp) {
            LpResult::Optimal { obj, x } => {
                assert!((obj + 1.0).abs() < 1e-9);
                assert!((x[0] + x[1] - 1.0).abs() < 1e-9);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn lp_equality_constraint() {
        // min x + 2y s.t. x + y = 1 -> x=1, y=0, obj 1.
        let lp = Lp {
            n: 2,
            c: vec![1.0, 2.0],
            a_eq: vec![vec![1.0, 1.0]],
            b_eq: vec![1.0],
            a_ub: vec![],
            b_ub: vec![],
        };
        match solve_lp(&lp) {
            LpResult::Optimal { obj, x } => {
                assert!((obj - 1.0).abs() < 1e-9);
                assert!((x[0] - 1.0).abs() < 1e-9);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn lp_detects_infeasible() {
        // x <= -1 with x >= 0.
        let lp = Lp {
            n: 1,
            c: vec![1.0],
            a_eq: vec![],
            b_eq: vec![],
            a_ub: vec![vec![1.0], vec![-1.0]],
            b_ub: vec![-1.0, -2.0], // x <= -1 and x >= 2: infeasible
        };
        assert_eq!(solve_lp(&lp), LpResult::Infeasible);
    }

    #[test]
    fn lp_detects_unbounded() {
        // min -x with no constraints.
        let lp = Lp {
            n: 1,
            c: vec![-1.0],
            a_eq: vec![],
            b_eq: vec![],
            a_ub: vec![],
            b_ub: vec![],
        };
        assert_eq!(solve_lp(&lp), LpResult::Unbounded);
    }

    #[test]
    fn bb_solves_tiny_instance_exactly() {
        // Two layers, clear optimum under budget 20:
        let prob = DeployProblem {
            layers: vec![
                vec![ch(1, 100.0, 5.0), ch(2, 60.0, 10.0), ch(4, 30.0, 20.0)],
                vec![ch(1, 80.0, 5.0), ch(2, 50.0, 10.0)],
            ],
            latency_budget: 20.0,
        };
        let (sol, _) = solve_bb(&prob).unwrap();
        // Best: layer0 j=1 (60, 10) + layer1 j=1 (50, 10) = 110 @ 20.
        assert_eq!(sol.cost, 110.0);
        assert_eq!(sol.latency, 20.0);
        assert_eq!(solve_dp(&prob).unwrap().cost, 110.0);
    }

    #[test]
    fn bb_infeasible_when_budget_too_tight() {
        let prob = DeployProblem {
            layers: vec![vec![ch(1, 1.0, 100.0)]],
            latency_budget: 50.0,
        };
        assert!(solve_bb(&prob).is_none());
        assert!(solve_dp(&prob).is_none());
    }

    #[test]
    fn prune_keeps_pareto_choices_only() {
        let prob = DeployProblem {
            layers: vec![vec![
                ch(1, 100.0, 10.0),
                ch(2, 120.0, 12.0), // dominated (worse both ways)
                ch(4, 50.0, 20.0),
                ch(8, 50.0, 30.0), // dominated (same cost, more latency)
            ]],
            latency_budget: 100.0,
        };
        let (pruned, maps) = prob.prune_dominated();
        assert_eq!(pruned.layers[0].len(), 2);
        assert_eq!(maps[0], vec![0, 2]);
    }

    #[test]
    fn property_bb_matches_dp_oracle() {
        prop_check("bb-equals-dp", 40, |g| {
            let mut rng = Rng::new(g.rng.next_u64());
            let n_layers = g.int(1, 6);
            let n_choices = g.int(2, 6);
            let prob = random_problem(&mut rng, n_layers, n_choices);
            let bb = solve_bb(&prob);
            let dp = solve_dp(&prob);
            match (bb, dp) {
                (None, None) => Ok(()),
                (Some((b, _)), Some(d)) => {
                    if (b.cost - d.cost).abs() < 1e-6 {
                        Ok(())
                    } else {
                        Err(format!(
                            "bb cost {} != dp cost {} (budget {})",
                            b.cost, d.cost, prob.latency_budget
                        ))
                    }
                }
                (b, d) => Err(format!(
                    "feasibility disagreement: bb {:?} dp {:?}",
                    b.map(|x| x.0.cost),
                    d.map(|x| x.cost)
                )),
            }
        });
    }

    #[test]
    fn property_solutions_respect_budget() {
        prop_check("solutions-within-budget", 30, |g| {
            let mut rng = Rng::new(g.rng.next_u64());
            let prob = random_problem(&mut rng, g.int(1, 8), g.int(2, 8));
            if let Some((sol, _)) = solve_bb(&prob) {
                if sol.latency > prob.latency_budget + 1e-6 {
                    return Err(format!(
                        "bb latency {} exceeds budget {}",
                        sol.latency, prob.latency_budget
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn with_budget_rebudgets_without_touching_choices() {
        let mut rng = Rng::new(0xB4D6);
        let prob = random_problem(&mut rng, 3, 4);
        let re = prob.with_budget(123.0);
        assert_eq!(re.latency_budget, 123.0);
        assert_eq!(re.layers, prob.layers);
        // Solving the re-budgeted copy is exactly a solve at that budget.
        let mut direct = prob.clone();
        direct.latency_budget = 123.0;
        assert_eq!(
            solve_bb(&re).map(|(s, _)| s),
            solve_bb(&direct).map(|(s, _)| s)
        );
    }

    #[test]
    fn permutation_count() {
        let prob = DeployProblem {
            layers: vec![
                vec![ch(1, 0.0, 0.0); 10],
                vec![ch(1, 0.0, 0.0); 20],
                vec![ch(1, 0.0, 0.0); 3],
            ],
            latency_budget: 1.0,
        };
        assert_eq!(prob.permutations(), 600.0);
    }

    #[test]
    fn bb_on_realistic_scale_fast() {
        // ~11 layers x ~40 choices: must solve in well under a second.
        let mut rng = Rng::new(77);
        let prob = random_problem(&mut rng, 11, 40);
        let t0 = std::time::Instant::now();
        let sol = solve_bb(&prob);
        assert!(sol.is_some());
        // Debug builds are ~20x slower than release; the perf bench
        // (perf_hotpaths) tracks the release-mode number (~0.1 s).
        assert!(t0.elapsed().as_secs_f64() < 20.0, "{:?}", t0.elapsed());
    }
}
