//! Bench E4 — Table II: our per-metric MAPE (best/median/worst across the
//! three layer types) against the Wu et al. [26] constants quoted in the
//! paper. The paper's claim: specialized HLS4ML models beat the generic
//! GNN predictor on best and median MAPE.

use ntorc::bench::Bencher;
use ntorc::coordinator::PipelineConfig;
use ntorc::report;

fn main() {
    let mut b = Bencher::new("table2_mape");
    let t0 = std::time::Instant::now();
    let (_pipe, models) = report::standard_models(PipelineConfig::default());
    b.record("standard_models/build", t0.elapsed().as_nanos() as f64);

    let (h, rows) = report::table2_rows(&models);
    println!("{}", report::fmt_table("Table II — MAPE vs Wu et al.", &h, &rows));
    report::write_csv("table2_mape", &h, &rows).expect("csv");

    // Shape check: our best-case MAPE beats Wu et al. on every metric they
    // report (the paper's headline for this table).
    let mut wins = 0;
    let mut total = 0;
    for row in &rows {
        if row[1] == "N/A" {
            continue;
        }
        let wu_best: f64 = row[1].parse().unwrap();
        let ours_best: f64 = row[2].parse().unwrap();
        total += 1;
        if ours_best < wu_best {
            wins += 1;
        }
    }
    println!("best-case MAPE wins: {wins}/{total}");
    assert!(wins * 2 >= total, "should win at least half the best-case comparisons");
    b.finish();
}
