//! Perf bench — the Layer-3 hot paths (EXPERIMENTS.md §Perf):
//!   * native-trainer GEMM + full train step (HPO inner loop),
//!   * random-forest inference (MIP candidate enumeration),
//!   * MIP B&B solve + DP oracle,
//!   * beam-simulator sample generation,
//!   * PJRT train/predict step (if artifacts are built).

use ntorc::bench::Bencher;
use ntorc::coordinator::{candidate_reuse_factors, Pipeline, PipelineConfig};
use ntorc::layers::{LayerKind, LayerSpec, NetConfig};
use ntorc::nn::{train_step, Adam, AdamConfig, NativeModel};
use ntorc::rng::Rng;
use ntorc::tensor::{matmul, Tensor};

fn main() {
    let mut b = Bencher::new("perf_hotpaths");
    let mut rng = Rng::new(1);

    // --- tensor GEMM (native-trainer inner loop) -------------------------
    for (m, k, n) in [(32, 256, 64), (64, 512, 128)] {
        let a = Tensor::from_vec(&[m, k], (0..m * k).map(|_| rng.f32() - 0.5).collect());
        let w = Tensor::from_vec(&[k, n], (0..k * n).map(|_| rng.f32() - 0.5).collect());
        let flops = 2.0 * (m * k * n) as f64;
        let meas = b.bench(&format!("gemm/{m}x{k}x{n}"), || matmul(&a, &w));
        let gflops = flops / meas.median_ns();
        println!("    -> {:.2} GFLOP/s", gflops);
    }

    // --- full native train step (quickstart-scale net) -------------------
    let cfg = NetConfig::new(64, vec![(5, 8)], vec![8], vec![16, 1]);
    let mut model = NativeModel::init(cfg.clone(), &mut rng);
    let mut opt = Adam::new(&model.params, AdamConfig::default());
    let batch = 32;
    let x = Tensor::from_vec(
        &[batch, 64],
        (0..batch * 64).map(|_| rng.f32() - 0.5).collect(),
    );
    let y: Vec<f32> = (0..batch).map(|_| rng.f32()).collect();
    b.bench("native_train_step/quickstart_b32", || {
        train_step(&mut model, &mut opt, &x, &y)
    });

    // --- cost-model inference + MIP ---------------------------------------
    let pipe = Pipeline::new(PipelineConfig::default());
    let db = pipe.synth_database();
    let models = pipe.fit_models(&db);
    let spec = LayerSpec::new(LayerKind::Dense, 512, 64, 1);
    b.bench("forest_predict/one_layer", || models.predict_layer(&spec, 32));

    let net = ntorc::report::table4_models()[0].1.clone();
    let prob = models.build_problem(&net.plan(), 50_000.0, 48);
    b.bench("mip_build_problem/model1", || {
        models.build_problem(&net.plan(), 50_000.0, 48).layers.len()
    });
    b.bench("mip_solve_bb/model1", || ntorc::mip::solve_bb(&prob).is_some());
    b.bench("mip_solve_dp/model1", || ntorc::mip::solve_dp(&prob).is_some());
    b.bench("stochastic_1k/model1", || {
        ntorc::search::stochastic_search(&prob, 1_000, 7).best.is_some()
    });

    // --- candidate enumeration -------------------------------------------
    b.bench("candidate_rfs/dense_512x64", || {
        candidate_reuse_factors(&spec, 48).len()
    });

    // --- beam simulator ----------------------------------------------------
    let sim = ntorc::dropbear::Simulator::new(ntorc::dropbear::SimConfig {
        table_points: 32,
        ..Default::default()
    });
    let meas = b.bench("dropbear_generate/1s_run", || {
        sim.generate(ntorc::dropbear::Profile::RandomDwell, 1.0, 3)
            .accel
            .len()
    });
    println!(
        "    -> {:.1}x realtime at 5 kHz",
        1e9 / meas.median_ns()
    );

    // --- PJRT steps (needs artifacts) --------------------------------------
    if std::path::Path::new("artifacts/quickstart.meta.json").exists() {
        let rt = ntorc::runtime::Runtime::new("artifacts").expect("client");
        let model = rt.load("quickstart").expect("load");
        let mut state = model.init_state(3).expect("state");
        let bx = Tensor::from_vec(
            &[model.meta.batch, model.meta.window],
            (0..model.meta.batch * model.meta.window)
                .map(|_| rng.f32() - 0.5)
                .collect(),
        );
        let by: Vec<f32> = (0..model.meta.batch).map(|_| rng.f32()).collect();
        b.bench("pjrt_train_step/quickstart_b32", || {
            model.train_step(&mut state, &bx, &by).unwrap()
        });
        let px = Tensor::from_vec(
            &[1, model.meta.window],
            (0..model.meta.window).map(|_| rng.f32() - 0.5).collect(),
        );
        let meas = b.bench("pjrt_predict/quickstart", || {
            model.predict_one(&state, &px).unwrap()
        });
        println!(
            "    -> single-window inference {:.1} µs (vs the paper's 200 µs real-time bound on FPGA)",
            meas.median_ns() / 1e3
        );
    } else {
        println!("artifacts not built; skipping PJRT hot paths");
    }
    b.finish();
}
