//! Perf bench — the Layer-3 hot paths (EXPERIMENTS.md §Perf):
//!   * native-trainer GEMM + full train step (HPO inner loop),
//!   * random-forest inference (MIP candidate enumeration),
//!   * batched vs unbatched cost-model grid evaluation (crate::eval),
//!   * analytical systolic collapse vs the batched forest collapse
//!     (>= 10x faster with zero forest calls — the closed-form
//!     acceptance bar, asserted here),
//!   * MIP B&B solve + DP oracle,
//!   * Pareto-frontier build / query / sweep (crate::frontier),
//!   * ε-dominance coarsened frontier vs exact on the adversarial
//!     wide-grid instance (>= 5x faster, >= 10x smaller, every answer
//!     within 1% — the acceptance bar, asserted here),
//!   * adaptive point-budget build vs fixed ε on the deep hub+chain
//!     plan (>= 5x faster at the same recorded cost error — the
//!     streaming-era acceptance bar, asserted here) and the FIFO-priced
//!     DP's <= 1.10x cost bar on shallow plans (docs/SOLVER.md),
//!   * frontier serving: cold build, warm LRU hit, batched endpoint and
//!     the store round-trip (crate::serve),
//!   * beam-simulator sample generation,
//!   * PJRT train/predict step (if artifacts are built).
//!
//! The frontier sections also write `results/BENCH_frontier.json`
//! (frontier build time, per-query time, sweep time, B&B solve time and
//! node count, plus the serve-path metrics). When `NTORC_BENCH_BASELINE`
//! points at a baseline JSON (CI uses the committed
//! `benches/BENCH_frontier.baseline.json`), any metric more than 2x
//! worse than its baseline value fails the run — except
//! `obs_overhead_ratio`, whose baseline stores the absolute 1.05 bound
//! (obs-on frontier build <= 5% over obs-off) and is compared directly.
//! The ratchet procedure is
//! documented in `benches/README.md`: copy a fresh
//! `results/BENCH_frontier.json` over the committed file (keep headroom:
//! CI runners are slow and shared).

use ntorc::backend::{Backend, SystolicBackend, SystolicParams};
use ntorc::bench::Bencher;
use ntorc::coordinator::{candidate_reuse_factors, Pipeline, PipelineConfig};
use ntorc::eval::BatchEvaluator;
use ntorc::frontier::ParetoFrontier;
use ntorc::hls::LayerCost;
use ntorc::layers::{LayerKind, LayerSpec, NetConfig};
use ntorc::mip::{Choice, DeployProblem, FifoModel};
use ntorc::nn::{train_step, Adam, AdamConfig, NativeModel};
use ntorc::rng::Rng;
use ntorc::ser::{parse_json, Json};
use ntorc::serve::{
    BatchOptions, BatchRequest, FrontierKey, FrontierService, FrontierStore, ServeConfig,
    ServedFrontier, StoreFormat,
};
use ntorc::tensor::{matmul, Tensor};

fn main() {
    let mut b = Bencher::new("perf_hotpaths");
    let mut rng = Rng::new(1);

    // --- tensor GEMM (native-trainer inner loop) -------------------------
    for (m, k, n) in [(32, 256, 64), (64, 512, 128)] {
        let a = Tensor::from_vec(&[m, k], (0..m * k).map(|_| rng.f32() - 0.5).collect());
        let w = Tensor::from_vec(&[k, n], (0..k * n).map(|_| rng.f32() - 0.5).collect());
        let flops = 2.0 * (m * k * n) as f64;
        let meas = b.bench(&format!("gemm/{m}x{k}x{n}"), || matmul(&a, &w));
        let gflops = flops / meas.median_ns();
        println!("    -> {:.2} GFLOP/s", gflops);
    }

    // --- full native train step (quickstart-scale net) -------------------
    let cfg = NetConfig::new(64, vec![(5, 8)], vec![8], vec![16, 1]);
    let mut model = NativeModel::init(cfg.clone(), &mut rng);
    let mut opt = Adam::new(&model.params, AdamConfig::default());
    let batch = 32;
    let x = Tensor::from_vec(
        &[batch, 64],
        (0..batch * 64).map(|_| rng.f32() - 0.5).collect(),
    );
    let y: Vec<f32> = (0..batch).map(|_| rng.f32()).collect();
    b.bench("native_train_step/quickstart_b32", || {
        train_step(&mut model, &mut opt, &x, &y)
    });

    // --- cost-model inference + MIP ---------------------------------------
    let pipe = Pipeline::new(PipelineConfig::default());
    let db = pipe.synth_database();
    let models = pipe.fit_models(&db);
    let spec = LayerSpec::new(LayerKind::Dense, 512, 64, 1);
    b.bench("forest_predict/one_layer_uncached", || {
        models.predict_layer_uncached(&spec, 32)
    });
    b.bench("forest_predict/one_layer_cached", || models.predict_layer(&spec, 32));

    // --- batched vs unbatched grid evaluation ------------------------------
    // The candidate grid the MIP collapse needs: every unique
    // (layer, reuse) of model1 at the default 48-choice cap.
    let net = ntorc::report::table4_models()[0].1.clone();
    let plan = net.plan();
    let rfs: Vec<Vec<usize>> = plan
        .iter()
        .map(|s| candidate_reuse_factors(s, 48))
        .collect();

    // Unbatched reference: one full forest walk per metric per row.
    let t0 = std::time::Instant::now();
    let unbatched_grid: Vec<Vec<LayerCost>> = plan
        .iter()
        .zip(&rfs)
        .map(|(s, list)| {
            list.iter()
                .map(|&r| models.predict_layer_uncached(s, r))
                .collect()
        })
        .collect();
    let unbatched_ns = t0.elapsed().as_nanos() as f64;
    let unbatched_meas = b.record("grid_eval/unbatched", unbatched_ns).clone();

    // Batched: exactly one Forest::predict_batch per (model, layer-grid),
    // verified against the process-wide forest counters.
    models.cache().clear();
    ntorc::forest::reset_prediction_counters();
    let t0 = std::time::Instant::now();
    let evaluator = BatchEvaluator::new(&models, 1);
    let stats = evaluator.prime(&plan, &rfs);
    let batched_ns = t0.elapsed().as_nanos() as f64;
    let batched_meas = b.record("grid_eval/batched", batched_ns).clone();
    assert_eq!(
        stats.batch_calls, stats.forests,
        "exactly one predict_batch per (model, layer-grid)"
    );
    assert_eq!(
        ntorc::forest::predict_batch_calls(),
        stats.forests as u64,
        "forest counters must agree with the evaluator's stats"
    );
    assert_eq!(
        ntorc::forest::predict_calls(),
        0,
        "the batched path must issue no per-row predicts"
    );
    println!(
        "    -> {} rows through {} forests in {} predict_batch calls, {:.1}x vs unbatched",
        stats.rows,
        stats.forests,
        stats.batch_calls,
        ntorc::bench::speedup(&unbatched_meas, &batched_meas)
    );

    // Bit-identity: the cached grid and solve_bb results match the
    // uncached path exactly.
    for (i, s) in plan.iter().enumerate() {
        for (k, &r) in rfs[i].iter().enumerate() {
            assert_eq!(
                models.predict_layer(s, r),
                unbatched_grid[i][k],
                "cached cost differs at layer {i} reuse {r}"
            );
        }
    }
    let prob = models.build_problem(&plan, 50_000.0, 48);
    let prob_uncached = DeployProblem {
        layers: unbatched_grid
            .iter()
            .zip(&rfs)
            .map(|(costs, list)| {
                costs
                    .iter()
                    .zip(list)
                    .map(|(c, &r)| Choice {
                        reuse: r,
                        cost: c.resource_sum(),
                        latency: c.latency,
                    })
                    .collect::<Vec<_>>()
            })
            .collect(),
        latency_budget: 50_000.0,
        fifo: None,
    };
    let sol_cached = ntorc::mip::solve_bb(&prob).map(|(s, _)| s);
    let sol_uncached = ntorc::mip::solve_bb(&prob_uncached).map(|(s, _)| s);
    assert_eq!(
        sol_cached, sol_uncached,
        "solve_bb must be bit-identical with and without the cache"
    );
    println!("    -> solve_bb bit-identical with and without the cache");

    // --- analytical systolic collapse (closed-form, zero forest calls) -----
    // The overlay backend's acceptance bar (docs/BACKENDS.md): collapsing
    // the same model1 plan through the systolic closed forms must be
    // >= 10x faster than the batched forest-predicted collapse above and
    // must never touch the forests at all. This bench is single-threaded,
    // so the process-wide prediction counters are exact here (they are
    // racy under `cargo test`'s parallel runner — which is why this
    // assertion lives here and not in a unit test).
    let systolic = SystolicBackend::new(SystolicParams::gemmini());
    ntorc::forest::reset_prediction_counters();
    let t0 = std::time::Instant::now();
    let sys_prob = systolic
        .build_problem(None, &plan, 50_000.0, 48, 1)
        .expect("closed-form backends build without models");
    let systolic_build_ns = t0.elapsed().as_nanos() as f64;
    b.record("systolic_build/model1", systolic_build_ns);
    assert_eq!(sys_prob.layers.len(), plan.len());
    assert_eq!(
        ntorc::forest::predict_batch_calls(),
        0,
        "the analytical path must issue no batched forest calls"
    );
    assert_eq!(
        ntorc::forest::predict_calls(),
        0,
        "the analytical path must issue no per-row forest calls"
    );
    assert!(
        systolic_build_ns * 10.0 <= batched_ns,
        "systolic collapse {systolic_build_ns}ns not 10x faster than batched forest {batched_ns}ns"
    );
    // The frontier engine runs backend-agnostic on the collapsed problem.
    let sys_index = ParetoFrontier::new(1).build(&sys_prob);
    sys_index.check_invariants().expect("systolic frontier invariants");
    println!(
        "    -> closed-form collapse {:.1} µs vs batched forest {:.1} µs ({:.1}x faster), \
         zero forest calls, {} frontier points",
        systolic_build_ns / 1e3,
        batched_ns / 1e3,
        batched_ns / systolic_build_ns.max(1.0),
        sys_index.len()
    );

    b.bench("mip_build_problem/model1", || {
        models.build_problem(&net.plan(), 50_000.0, 48).layers.len()
    });
    let bb_meas = b
        .bench("mip_solve_bb/model1", || ntorc::mip::solve_bb(&prob).is_some())
        .clone();
    b.bench("mip_solve_dp/model1", || ntorc::mip::solve_dp(&prob).is_some());
    b.bench("stochastic_1k/model1", || {
        ntorc::search::stochastic_search(&prob, 1_000, 7).best.is_some()
    });

    // --- Pareto-frontier engine --------------------------------------------
    // One dominance-pruned sweep answers every latency budget; per-budget
    // queries are O(log n) index lookups instead of fresh B&B solves.
    let t0 = std::time::Instant::now();
    let findex = ParetoFrontier::new(1).build(&prob);
    let frontier_build_ns = t0.elapsed().as_nanos() as f64;
    b.record("frontier_build/model1", frontier_build_ns);
    println!(
        "    -> {} frontier points from {} candidates ({} pruned)",
        findex.stats.points, findex.stats.candidates, findex.stats.pruned
    );
    findex.check_invariants().expect("frontier invariants");
    let query_meas = b
        .bench("frontier_query/model1", || findex.query(50_000.0).is_some())
        .clone();
    let budgets: Vec<f64> = (1..=64).map(|i| 4_000.0 * i as f64).collect();
    let t0 = std::time::Instant::now();
    let swept = findex.sweep(&budgets);
    let frontier_sweep_ns = t0.elapsed().as_nanos() as f64;
    b.record("frontier_sweep/64_budgets", frontier_sweep_ns);
    assert!(swept.iter().filter(|s| s.is_some()).count() >= 1);
    // B&B fallback cross-check at the real-time budget. Same relative
    // tolerance as FrontierIndex::cross_check_bb: solve_bb is exact only
    // up to its own prune slack, and a tied alternate optimum can sum
    // different addends in the last ulp.
    let (bb_sol, bb_stats) = ntorc::mip::solve_bb(&prob).expect("feasible at 200 µs");
    let frontier_sol = findex.query(50_000.0).expect("feasible at 200 µs");
    assert!(
        (frontier_sol.cost - bb_sol.cost).abs() <= 1e-9 * (1.0 + bb_sol.cost.abs()),
        "frontier query {} must match solve_bb {}",
        frontier_sol.cost,
        bb_sol.cost
    );
    println!(
        "    -> frontier query == solve_bb at 50k cycles (B&B expanded {} nodes)",
        bb_stats.nodes
    );

    // --- frontier serving (store + LRU + batch endpoint) --------------------
    // Cold resolve = problem collapse + frontier DP + store persist; warm
    // resolve = LRU lookup; a second service session must answer from the
    // persisted document without building.
    let serve_dir =
        std::env::temp_dir().join(format!("ntorc_serve_bench_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&serve_dir);
    let serve_cfg = ServeConfig {
        capacity: 8,
        workers: 1,
        max_choices_per_layer: 48,
        latency_budget: 50_000.0,
        ..ServeConfig::default()
    };
    let svc = FrontierService::new(serve_cfg.clone(), Some(FrontierStore::new(&serve_dir)));
    let t0 = std::time::Instant::now();
    let cold = svc.resolve(&models, &net);
    let serve_cold_ns = t0.elapsed().as_nanos() as f64;
    b.record("serve_cold_build/model1", serve_cold_ns);
    assert_eq!(svc.stats.snapshot().builds, 1);
    let warm_meas = b
        .bench("serve_warm_hit/model1", || svc.resolve(&models, &net).index.len())
        .clone();
    assert_eq!(svc.stats.snapshot().builds, 1, "warm resolves must not rebuild");

    let net2 = ntorc::report::table4_models()[1].1.clone();
    let requests: Vec<BatchRequest> = (1..=32)
        .flat_map(|i| {
            let budget = 8_000.0 * i as f64;
            [
                BatchRequest { net: net.clone(), budget },
                BatchRequest { net: net2.clone(), budget },
            ]
        })
        .collect();
    let t0 = std::time::Instant::now();
    let responses = svc.batch(&requests, &BatchOptions::models(&models));
    let serve_batch_ns = t0.elapsed().as_nanos() as f64;
    assert_eq!(responses.len(), 64);
    let serve_batch_ns_per_query = serve_batch_ns / responses.len() as f64;
    b.record("serve_batch/64_requests", serve_batch_ns);
    println!(
        "    -> {} batched requests, {:.2} µs/query amortized (incl. one cold build)",
        responses.len(),
        serve_batch_ns_per_query / 1e3
    );

    // Second session over the same store: zero builds, identical points.
    let svc2 = FrontierService::new(serve_cfg, Some(FrontierStore::new(&serve_dir)));
    let reloaded = svc2.resolve(&models, &net);
    let snap2 = svc2.stats.snapshot();
    assert_eq!(snap2.builds, 0, "second session must serve from the store");
    assert_eq!(snap2.store_hits, 1);
    assert_eq!(reloaded.index.len(), cold.index.len());
    for i in 0..cold.index.len() {
        assert_eq!(reloaded.index.point(i), cold.index.point(i), "stored point {i}");
    }
    println!(
        "    -> store round-trip identical ({} points); second session builds=0",
        cold.index.len()
    );
    let _ = std::fs::remove_dir_all(&serve_dir);

    // --- ε-dominance coarsened frontier on an adversarial wide grid --------
    // The instance every `max_points`-style heuristic fears: 10 layers x
    // 4 choices where EVERY one of the 4^10 = 1,048,576 assignments is
    // Pareto-optimal (distinct base-4 latencies, cost linear in them).
    // The exact DP must materialize all of them; ε=0.01 caps each level
    // near ln(cost range)/δ points with the proven (1+ε) answer bound.
    let wide = ntorc::frontier::adversarial_wide_grid(10, 4);
    let t0 = std::time::Instant::now();
    let exact_wide = ParetoFrontier::new(1).build(&wide);
    let exact_wide_ns = t0.elapsed().as_nanos() as f64;
    b.record("frontier_wide_exact_build/4pow10", exact_wide_ns);
    assert_eq!(exact_wide.len(), 1 << 20, "every assignment is Pareto by construction");
    let t0 = std::time::Instant::now();
    let eps_wide = ParetoFrontier::new(1).with_epsilon(Some(0.01)).build(&wide);
    let eps_build_ns = t0.elapsed().as_nanos() as f64;
    b.record("frontier_wide_eps_build/4pow10", eps_build_ns);
    eps_wide.check_invariants().expect("eps frontier invariants");
    let eps_points_ratio = eps_wide.len() as f64 / exact_wide.len() as f64;
    println!(
        "    -> eps=0.01: {} points vs exact {} ({:.1}x smaller), build {:.1} ms vs {:.1} ms \
         ({:.1}x faster), {} entries coarsened away",
        eps_wide.len(),
        exact_wide.len(),
        1.0 / eps_points_ratio,
        eps_build_ns / 1e6,
        exact_wide_ns / 1e6,
        exact_wide_ns / eps_build_ns.max(1.0),
        eps_wide.stats.eps_pruned
    );
    // The PR's acceptance bar: >= 5x faster, >= 10x smaller, and every
    // sweep answer within 1% of the exact optimum (the exact index IS
    // the per-budget optimum here — it holds every assignment).
    assert!(
        eps_build_ns * 5.0 <= exact_wide_ns,
        "eps build {eps_build_ns}ns not 5x faster than exact {exact_wide_ns}ns"
    );
    assert!(
        eps_wide.len() * 10 <= exact_wide.len(),
        "eps frontier {} not 10x smaller than exact {}",
        eps_wide.len(),
        exact_wide.len()
    );
    let max_wide_latency: f64 = wide
        .layers
        .iter()
        .map(|l| l.iter().map(|c| c.latency).fold(0.0, f64::max))
        .sum();
    let mut verified = 0usize;
    for i in 0..=64u64 {
        let budget = max_wide_latency * i as f64 / 64.0;
        match (exact_wide.query(budget), eps_wide.query(budget)) {
            (None, None) => {}
            (Some(e), Some(a)) => {
                assert!(a.latency <= budget + 1e-9, "budget {budget}");
                assert!(a.cost >= e.cost - 1e-9, "budget {budget}: eps beats exact");
                assert!(
                    a.cost <= 1.01 * e.cost * (1.0 + 1e-12),
                    "budget {budget}: eps {} outside 1% of exact {}",
                    a.cost,
                    e.cost
                );
                verified += 1;
            }
            other => panic!("budget {budget}: feasibility disagreement {other:?}"),
        }
    }
    println!("    -> {verified} sweep answers verified within 1% of the exact optimum");

    // --- adaptive ε vs fixed ε on the deep hub+chain plan ------------------
    // The streaming-era acceptance bar (docs/SOLVER.md): on
    // `adversarial_deep_plan(32, 4)` — one 4^6-choice all-Pareto hub with
    // an e^25 multiplicative cost span, followed by 31 forced chain
    // layers — a fixed ε splits its error budget across all 32 levels,
    // leaving a per-level δ too fine to merge the hub staircase, so it
    // drags ~4096 points through every chain level. The adaptive
    // point-budget build spends its error where the points are (one big
    // δ at the hub) and carries ~256 points instead. At the SAME
    // worst-case cost error — the fixed build runs at ε equal to the
    // adaptive build's recorded eps_effective — adaptive must be >= 5x
    // faster.
    let deep = ntorc::frontier::adversarial_deep_plan(32, 4);
    let deep_budget = 256usize;
    let min_of = |build: &dyn Fn() -> ntorc::frontier::FrontierIndex| -> (f64, ntorc::frontier::FrontierIndex) {
        // min-of-3 with a warmup pass: wall-clock on shared runners.
        let mut best_ns = f64::INFINITY;
        let mut out = None;
        for i in 0..=3 {
            let t0 = std::time::Instant::now();
            let f = build();
            let ns = t0.elapsed().as_nanos() as f64;
            if i > 0 && ns < best_ns {
                best_ns = ns;
            }
            out = Some(f);
        }
        (best_ns, out.unwrap())
    };
    let (deep_build_ns, deep_adaptive) = min_of(&|| {
        ParetoFrontier::new(1).with_point_budget(Some(deep_budget)).build(&deep)
    });
    deep_adaptive.check_invariants().expect("deep adaptive invariants");
    let deep_eps = deep_adaptive.stats.eps_effective;
    assert!(deep_eps > 0.0, "the hub must overflow the budget and spend error");
    let (deep_fixed_ns, deep_fixed) = min_of(&|| {
        ParetoFrontier::new(1).with_epsilon(Some(deep_eps)).build(&deep)
    });
    deep_fixed.check_invariants().expect("deep fixed-eps invariants");
    b.record("deep_adaptive_build/32x4", deep_build_ns);
    b.record("deep_fixed_eps_build/32x4", deep_fixed_ns);
    let deep_points_ratio = deep_adaptive.len() as f64 / deep_budget as f64;
    println!(
        "    -> adaptive(budget {deep_budget}) {:.1} ms / {} points vs fixed eps={:.4} {:.1} ms \
         / {} points ({:.1}x faster at the same recorded bound)",
        deep_build_ns / 1e6,
        deep_adaptive.len(),
        deep_eps,
        deep_fixed_ns / 1e6,
        deep_fixed.len(),
        deep_fixed_ns / deep_build_ns.max(1.0)
    );
    assert!(
        deep_build_ns * 5.0 <= deep_fixed_ns,
        "adaptive deep build {deep_build_ns}ns not 5x faster than fixed-eps {deep_fixed_ns}ns \
         at equal cost error {deep_eps}"
    );
    // Both builds honor the shared bound against the exact deep frontier
    // (feasible here: the chain layers are single-choice, so the exact
    // DP carries only the hub's 4096 points).
    let deep_exact = ParetoFrontier::new(1).build(&deep);
    let deep_max_latency: f64 = deep
        .layers
        .iter()
        .map(|l| l.iter().map(|c| c.latency).fold(0.0, f64::max))
        .sum();
    for i in 0..=32u64 {
        let budget = deep_max_latency * i as f64 / 32.0;
        match (deep_exact.query(budget), deep_adaptive.query(budget)) {
            (None, None) => {}
            (Some(e), Some(a)) => {
                assert!(a.latency <= budget + 1e-9, "deep budget {budget}");
                assert!(
                    a.cost <= (1.0 + deep_eps) * e.cost * (1.0 + 1e-12),
                    "deep budget {budget}: adaptive {} outside (1+{deep_eps}) of exact {}",
                    a.cost,
                    e.cost
                );
            }
            other => panic!("deep budget {budget}: feasibility disagreement {other:?}"),
        }
    }
    println!("    -> adaptive answers verified within (1+{deep_eps:.4})x of the exact deep optimum");

    // --- FIFO-priced DP overhead on the shallow model1 plan ----------------
    // Streaming cost model sanity bar: pricing inter-layer stream buffers
    // (FifoModel) must not distort shallow plans — the FIFO-aware optimum
    // at the real-time budget, stream buffers included, stays within 10%
    // of the FIFO-free optimum.
    let fifo_widths: Vec<f64> =
        plan[..plan.len() - 1].iter().map(|l| l.n_out as f64).collect();
    let prob_fifo = prob.with_fifo(FifoModel {
        cost_per_slot: 0.5,
        min_depth: 0.0,
        widths: fifo_widths,
    });
    let t0 = std::time::Instant::now();
    let fifo_index = ParetoFrontier::new(1).build(&prob_fifo);
    let fifo_build_ns = t0.elapsed().as_nanos() as f64;
    b.record("frontier_fifo_build/model1", fifo_build_ns);
    fifo_index.check_invariants().expect("fifo frontier invariants");
    let fifo_sol = fifo_index.query(50_000.0).expect("feasible at 200 µs with FIFO pricing");
    let fifo_overhead_ratio = fifo_sol.cost / frontier_sol.cost;
    println!(
        "    -> FIFO-priced optimum {:.0} (buffers {:.0}) vs FIFO-free {:.0} ({:.3}x)",
        fifo_sol.cost,
        prob_fifo.fifo_cost_of(&fifo_sol.pick),
        frontier_sol.cost,
        fifo_overhead_ratio
    );
    assert!(
        fifo_overhead_ratio >= 1.0 - 1e-9,
        "FIFO pricing cannot make the optimum cheaper: {fifo_overhead_ratio}"
    );
    assert!(
        fifo_overhead_ratio <= 1.10,
        "FIFO-priced optimum {fifo_overhead_ratio}x over the FIFO-free optimum (bar: 1.10)"
    );

    // --- observability overhead (obs-on vs obs-off frontier build) ---------
    // The [obs] acceptance bar (docs/OBSERVABILITY.md): with tracing
    // enabled AND a live trace installed — so every build/level{k} and
    // eps_prune span actually records — the eps wide-grid build must stay
    // within 5% of the obs-off build. min-of-N with a warmup pass sheds
    // scheduler noise; the baseline stores the 1.05 bound itself and the
    // gate below compares directly against it (not the generic 2x rule).
    let obs_bench = |n: usize| -> f64 {
        let mut best = f64::INFINITY;
        for i in 0..=n {
            let t0 = std::time::Instant::now();
            let f = ParetoFrontier::new(1).with_epsilon(Some(0.01)).build(&wide);
            let ns = t0.elapsed().as_nanos() as f64;
            assert!(!f.is_empty());
            if i > 0 {
                // Iteration 0 is the warmup.
                best = best.min(ns);
            }
        }
        best
    };
    let obs_off_ns = obs_bench(7);
    let obs_dir = std::env::temp_dir().join(format!("ntorc_bench_obs_{}", std::process::id()));
    let _ = std::fs::create_dir_all(&obs_dir);
    let obs_cfg = ntorc::obs::ObsConfig {
        enabled: true,
        log_path: obs_dir.join("obs.jsonl").to_string_lossy().into_owned(),
        ..Default::default()
    };
    ntorc::obs::init(&obs_cfg).expect("obs init");
    let obs_trace = ntorc::obs::Trace::new(ntorc::obs::next_trace_id());
    let obs_guard = ntorc::obs::install(std::sync::Arc::clone(&obs_trace));
    let obs_on_ns = obs_bench(7);
    drop(obs_guard);
    ntorc::obs::init(&ntorc::obs::ObsConfig::default()).expect("obs reset");
    let _ = std::fs::remove_dir_all(&obs_dir);
    let obs_overhead_ratio = obs_on_ns / obs_off_ns.max(1.0);
    b.record("obs_eps_build_on/4pow10", obs_on_ns);
    b.record("obs_eps_build_off/4pow10", obs_off_ns);
    println!(
        "    -> obs-on {:.1} ms vs obs-off {:.1} ms ({:.3}x overhead, {} spans recorded)",
        obs_on_ns / 1e6,
        obs_off_ns / 1e6,
        obs_overhead_ratio,
        obs_trace.spans().len()
    );

    // --- binary vs JSON store codec on the wide-grid frontier --------------
    // The store-format acceptance bar (docs/STORE_FORMAT.md): on the
    // 4^10-point exact frontier a binary cold load must be >= 5x faster
    // than the JSON parse and spend <= 0.5x the bytes per point.
    let wide_key = FrontierKey { hash: 0x51DE_6121D, name: "wide-4pow10".to_string() };
    let sf_wide = ServedFrontier::from_problem(wide_key.clone(), &wide, exact_wide);
    let json_dir = std::env::temp_dir().join(format!("ntorc_bench_sj_{}", std::process::id()));
    let bin_dir = std::env::temp_dir().join(format!("ntorc_bench_sb_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&json_dir);
    let _ = std::fs::remove_dir_all(&bin_dir);
    let json_store = FrontierStore::new(&json_dir);
    let bin_store = FrontierStore::new(&bin_dir).with_format(StoreFormat::Bin);
    let json_path = json_store.save(&sf_wide).expect("json save");
    let bin_path = bin_store.save(&sf_wide).expect("bin save");
    let points = sf_wide.index.len() as f64;
    let json_bytes = std::fs::metadata(&json_path).expect("json doc").len() as f64;
    let bin_bytes = std::fs::metadata(&bin_path).expect("bin doc").len() as f64;
    let t0 = std::time::Instant::now();
    let via_json = json_store.load(&wide_key).expect("json load").expect("json doc present");
    let json_load_ns = t0.elapsed().as_nanos() as f64;
    b.record("store_load_json/4pow10", json_load_ns);
    let t0 = std::time::Instant::now();
    let via_bin = bin_store.load(&wide_key).expect("bin load").expect("bin doc present");
    let store_load_ns = t0.elapsed().as_nanos() as f64;
    b.record("store_load_bin/4pow10", store_load_ns);
    let store_bytes_per_point = bin_bytes / points;
    assert_eq!(via_bin.index.len(), via_json.index.len());
    for i in [0usize, 1, 1 << 10, (1 << 20) - 1] {
        assert_eq!(via_bin.index.point(i), via_json.index.point(i), "stored point {i}");
        assert_eq!(via_bin.index.pick(i), via_json.index.pick(i), "stored pick {i}");
    }
    println!(
        "    -> bin load {:.1} ms vs json {:.1} ms ({:.1}x faster); {:.1} B/pt vs {:.1} B/pt \
         ({:.2}x)",
        store_load_ns / 1e6,
        json_load_ns / 1e6,
        json_load_ns / store_load_ns.max(1.0),
        store_bytes_per_point,
        json_bytes / points,
        bin_bytes / json_bytes
    );
    assert!(
        store_load_ns * 5.0 <= json_load_ns,
        "bin load {store_load_ns}ns not 5x faster than json {json_load_ns}ns"
    );
    assert!(
        bin_bytes * 2.0 <= json_bytes,
        "bin doc {bin_bytes}B not half the json doc {json_bytes}B"
    );
    let _ = std::fs::remove_dir_all(&json_dir);
    let _ = std::fs::remove_dir_all(&bin_dir);

    // Regression report + gate (see module docs).
    let report = Json::obj(vec![
        ("frontier_build_ns", Json::num(frontier_build_ns)),
        ("frontier_query_ns", Json::num(query_meas.median_ns())),
        ("frontier_sweep_ns", Json::num(frontier_sweep_ns)),
        ("frontier_points", Json::num(findex.stats.points as f64)),
        ("bb_solve_ns", Json::num(bb_meas.median_ns())),
        ("bb_nodes", Json::num(bb_stats.nodes as f64)),
        ("serve_cold_ns", Json::num(serve_cold_ns)),
        ("serve_warm_ns", Json::num(warm_meas.median_ns())),
        ("serve_batch_ns_per_query", Json::num(serve_batch_ns_per_query)),
        ("eps_build_ns", Json::num(eps_build_ns)),
        ("eps_points_ratio", Json::num(eps_points_ratio)),
        ("deep_build_ns", Json::num(deep_build_ns)),
        ("deep_points_ratio", Json::num(deep_points_ratio)),
        ("fifo_overhead_ratio", Json::num(fifo_overhead_ratio)),
        ("obs_overhead_ratio", Json::num(obs_overhead_ratio)),
        ("store_load_ns", Json::num(store_load_ns)),
        ("store_bytes_per_point", Json::num(store_bytes_per_point)),
        ("systolic_build_ns", Json::num(systolic_build_ns)),
    ]);
    std::fs::create_dir_all("results").expect("results dir");
    std::fs::write("results/BENCH_frontier.json", report.to_pretty()).expect("bench json");
    println!("[perf_hotpaths] wrote results/BENCH_frontier.json");
    // Ready-to-commit ratchet candidate: measured values with the
    // recommended headroom applied (3x for wall-clock metrics — shared
    // runners are noisy, and the gate adds its own 2x — exact for the
    // machine-independent bb_nodes counter). The CI artifact carries
    // this next to the raw report so a baseline ratchet is a review +
    // copy over benches/BENCH_frontier.baseline.json (see README.md).
    let ratchet = |key: &str| {
        let v = report.get(key).unwrap().as_f64().unwrap();
        if key == "bb_nodes" {
            v.ceil()
        } else if key == "obs_overhead_ratio" {
            // Fixed acceptance bound (obs-on <= 5% over obs-off), never
            // ratcheted from a measurement.
            1.05
        } else if key == "fifo_overhead_ratio" {
            // Fixed acceptance bound (FIFO-priced shallow optimum <= 10%
            // over the FIFO-free optimum), never ratcheted.
            1.10
        } else if key == "eps_points_ratio"
            || key == "deep_points_ratio"
            || key == "store_bytes_per_point"
        {
            // Machine-independent size metrics, not wall-clock: 2x
            // headroom without the integer ceil.
            2.0 * v
        } else {
            (3.0 * v).ceil()
        }
    };
    let ratchet_doc = Json::obj(vec![
        (
            "_comment",
            Json::str(
                "Suggested next baseline: measured medians x3 headroom (bb_nodes exact). \
                 Review against benches/README.md before committing."
                    .to_string(),
            ),
        ),
        ("bb_nodes", Json::num(ratchet("bb_nodes"))),
        ("bb_solve_ns", Json::num(ratchet("bb_solve_ns"))),
        ("frontier_build_ns", Json::num(ratchet("frontier_build_ns"))),
        ("frontier_query_ns", Json::num(ratchet("frontier_query_ns"))),
        ("frontier_sweep_ns", Json::num(ratchet("frontier_sweep_ns"))),
        ("serve_cold_ns", Json::num(ratchet("serve_cold_ns"))),
        ("serve_warm_ns", Json::num(ratchet("serve_warm_ns"))),
        (
            "serve_batch_ns_per_query",
            Json::num(ratchet("serve_batch_ns_per_query")),
        ),
        ("eps_build_ns", Json::num(ratchet("eps_build_ns"))),
        ("eps_points_ratio", Json::num(ratchet("eps_points_ratio"))),
        ("deep_build_ns", Json::num(ratchet("deep_build_ns"))),
        ("deep_points_ratio", Json::num(ratchet("deep_points_ratio"))),
        ("fifo_overhead_ratio", Json::num(ratchet("fifo_overhead_ratio"))),
        ("obs_overhead_ratio", Json::num(ratchet("obs_overhead_ratio"))),
        ("store_load_ns", Json::num(ratchet("store_load_ns"))),
        (
            "store_bytes_per_point",
            Json::num(ratchet("store_bytes_per_point")),
        ),
        ("systolic_build_ns", Json::num(ratchet("systolic_build_ns"))),
    ]);
    std::fs::write("results/BENCH_frontier.ratchet.json", ratchet_doc.to_pretty())
        .expect("ratchet json");
    println!("[perf_hotpaths] wrote results/BENCH_frontier.ratchet.json (ratchet candidate)");
    if let Ok(path) = std::env::var("NTORC_BENCH_BASELINE") {
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("cannot read baseline {path}: {e}"));
        let baseline = parse_json(&text).expect("baseline JSON");
        let mut failures = Vec::new();
        for key in [
            "frontier_build_ns",
            "frontier_query_ns",
            "frontier_sweep_ns",
            "bb_solve_ns",
            "bb_nodes",
            "serve_cold_ns",
            "serve_warm_ns",
            "serve_batch_ns_per_query",
            "eps_build_ns",
            "eps_points_ratio",
            "deep_build_ns",
            "deep_points_ratio",
            "fifo_overhead_ratio",
            "obs_overhead_ratio",
            "store_load_ns",
            "store_bytes_per_point",
            "systolic_build_ns",
        ] {
            let measured = report.get(key).unwrap().as_f64().unwrap();
            // Keys absent from the baseline are not gated (lets the
            // baseline trail new metrics without breaking CI).
            if let Some(base) = baseline.get(key).ok().and_then(|j| j.as_f64()) {
                // obs_overhead_ratio and fifo_overhead_ratio are absolute
                // bounds: the baseline stores the ceiling itself (1.05 /
                // 1.10), so the generic 2x headroom does not apply.
                let limit = if key == "obs_overhead_ratio" || key == "fifo_overhead_ratio" {
                    base
                } else {
                    2.0 * base
                };
                if measured > limit {
                    failures.push(format!(
                        "{key}: {measured:.3} > limit {limit:.3} (baseline {base:.3})"
                    ));
                } else {
                    println!("    {key}: {measured:.3} vs limit {limit:.3} ok");
                }
            }
        }
        if !failures.is_empty() {
            eprintln!("[perf_hotpaths] bench regression vs {path}:");
            for f in &failures {
                eprintln!("  {f}");
            }
            std::process::exit(1);
        }
        println!("[perf_hotpaths] frontier metrics within their limits vs baseline {path}");
    }

    // --- candidate enumeration -------------------------------------------
    b.bench("candidate_rfs/dense_512x64", || {
        candidate_reuse_factors(&spec, 48).len()
    });

    // --- workload simulators -----------------------------------------------
    let sim = ntorc::dropbear::Simulator::new(ntorc::dropbear::SimConfig {
        table_points: 32,
        ..Default::default()
    });
    let meas = b.bench("dropbear_generate/1s_run", || {
        sim.generate(ntorc::dropbear::Profile::RandomDwell, 1.0, 3)
            .input
            .len()
    });
    println!(
        "    -> {:.1}x realtime at 5 kHz",
        1e9 / meas.median_ns()
    );
    let rotor = ntorc::rotor::RotorSim::new(ntorc::rotor::RotorConfig::default());
    let meas = b.bench("rotor_generate/1s_run", || {
        rotor
            .generate(ntorc::rotor::RotorProfile::RandomLoad, 1.0, 3)
            .input
            .len()
    });
    println!("    -> {:.1}x realtime at 50 kHz", 1e9 / meas.median_ns());
    let battery = ntorc::battery::BatterySim::new(ntorc::battery::BatteryConfig::default());
    let meas = b.bench("battery_generate/1s_run", || {
        battery
            .generate(ntorc::battery::BatteryProfile::RandomWalk, 1.0, 3)
            .input
            .len()
    });
    println!("    -> {:.1}x realtime at 500 Hz", 1e9 / meas.median_ns());

    // --- PJRT steps (needs artifacts) --------------------------------------
    if std::path::Path::new("artifacts/quickstart.meta.json").exists() {
        let rt = ntorc::runtime::Runtime::new("artifacts").expect("client");
        let model = rt.load("quickstart").expect("load");
        let mut state = model.init_state(3).expect("state");
        let bx = Tensor::from_vec(
            &[model.meta.batch, model.meta.window],
            (0..model.meta.batch * model.meta.window)
                .map(|_| rng.f32() - 0.5)
                .collect(),
        );
        let by: Vec<f32> = (0..model.meta.batch).map(|_| rng.f32()).collect();
        b.bench("pjrt_train_step/quickstart_b32", || {
            model.train_step(&mut state, &bx, &by).unwrap()
        });
        let px = Tensor::from_vec(
            &[1, model.meta.window],
            (0..model.meta.window).map(|_| rng.f32() - 0.5).collect(),
        );
        let meas = b.bench("pjrt_predict/quickstart", || {
            model.predict_one(&state, &px).unwrap()
        });
        println!(
            "    -> single-window inference {:.1} µs (vs the paper's 200 µs real-time bound on FPGA)",
            meas.median_ns() / 1e3
        );
    } else {
        println!("artifacts not built; skipping PJRT hot paths");
    }
    b.finish();
}
