//! Bench E6 — Fig 7: train two models of different capacity and trace
//! them against the ground-truth roller position on a standard-index test
//! run. Shape claim: the lower-RMSE model tracks the roller better.

use ntorc::bench::Bencher;
use ntorc::coordinator::{DataConfig, TrainBudget};
use ntorc::layers::NetConfig;
use ntorc::report;

fn main() {
    let mut b = Bencher::new("fig7_trace");
    let fast = std::env::var("NTORC_BENCH_FAST").is_ok();
    let sim = report::standard_workload("dropbear");
    let dc = DataConfig {
        seconds_per_run: if fast { 1.0 } else { 3.0 },
        ..DataConfig::smoke()
    };
    let budget = TrainBudget {
        steps: if fast { 60 } else { 400 },
        ..TrainBudget::smoke()
    };
    // "model 2" (higher capacity, conv+lstm+dense) vs "model 1" (small).
    let configs = vec![
        ("strong", NetConfig::new(64, vec![(3, 8)], vec![8], vec![16, 1])),
        ("weak", NetConfig::new(32, vec![], vec![], vec![4, 1])),
    ];
    let named: Vec<(&str, NetConfig)> = configs.iter().map(|(n, c)| (*n, c.clone())).collect();

    let t0 = std::time::Instant::now();
    let out = report::fig7_run(&sim, &dc, &named, &budget, 0xF1607);
    b.record("fig7_run/train+trace", t0.elapsed().as_nanos() as f64);

    for (name, rmse) in &out.rmse {
        println!("{name}: trace RMSE {rmse:.4}");
        assert!(rmse.is_finite() && *rmse < 1.0);
    }
    let headers = vec!["t_s", "vibration", "roller_true", "pred_strong", "pred_weak"];
    report::write_csv("fig7_trace", &headers, &out.rows).expect("csv");
    println!("trace rows: {} -> results/fig7_trace.csv", out.rows.len());
    // The capacity ordering should show up as an RMSE ordering (the Fig 7
    // cyan-vs-red comparison); allow slack for tiny training budgets.
    if !fast {
        let strong = out.rmse.iter().find(|(n, _)| n == "strong").unwrap().1;
        let weak = out.rmse.iter().find(|(n, _)| n == "weak").unwrap().1;
        assert!(
            strong <= weak * 1.25,
            "higher-capacity model should track at least as well: {strong} vs {weak}"
        );
    }
    b.finish();
}
