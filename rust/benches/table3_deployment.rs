//! Bench E5 — Table III: MIP deployment of the Pareto set under the
//! 200 µs constraint: every deployed model must meet the budget, and
//! resource cost must broadly track workload (the paper notes occasional
//! inversions from model error — we allow them but count them).

use ntorc::bench::Bencher;
use ntorc::coordinator::{Pipeline, PipelineConfig};
use ntorc::report;

fn main() {
    let mut b = Bencher::new("table3_deployment");
    let fast = std::env::var("NTORC_BENCH_FAST").is_ok();
    let mut cfg = PipelineConfig::smoke();
    cfg.hpo.n_trials = if fast { 8 } else { 20 };
    cfg.budget.steps = if fast { 50 } else { 140 };
    cfg.hpo.space = ntorc::hpo::SearchSpace::default();
    // Use the full sweep for trustworthy cost models.
    cfg.sweep = ntorc::hls::SweepConfig::default();
    let pipe = Pipeline::new(cfg);

    let t0 = std::time::Instant::now();
    let db = pipe.synth_database();
    let models = pipe.fit_models(&db);
    b.record("models/build", t0.elapsed().as_nanos() as f64);

    let sim = pipe.workload();
    let out = report::fig5_run(&pipe, &sim);
    let t0 = std::time::Instant::now();
    let deployed = report::deploy_pareto(&pipe, &models, &out.trials);
    b.record("deploy_pareto/total", t0.elapsed().as_nanos() as f64);
    assert!(!deployed.is_empty(), "nothing deployed");

    let (h, rows) = report::table3_rows(&deployed);
    println!("{}", report::fmt_table("Table III — deployed Pareto networks", &h, &rows));
    report::write_csv("table3_deployment", &h, &rows).expect("csv");

    let mut inversions = 0;
    for w in deployed.windows(2) {
        // Sorted by descending RMSE => ascending workload; cost should
        // *generally* rise (paper rows 8-11 show exceptions).
        assert!(w[0].latency_us <= 200.0 + 1e-6);
        assert!(w[1].latency_us <= 200.0 + 1e-6);
        if w[1].predicted.resource_sum() < w[0].predicted.resource_sum() {
            inversions += 1;
        }
    }
    println!(
        "{} deployments, {} cost/workload inversions (paper also shows a few)",
        deployed.len(),
        inversions
    );
    assert!(inversions <= deployed.len() / 2, "cost should broadly track workload");
    b.finish();
}
