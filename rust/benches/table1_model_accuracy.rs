//! Bench E3 — Table I: regenerate the cost/latency model validation table
//! on the full synthesis sweep and assert the paper's qualitative shape:
//! latency nearly perfect, resources good-but-noisier, LSTM BRAM worst.

use ntorc::bench::Bencher;
use ntorc::coordinator::{CostModels, Pipeline, PipelineConfig};
use ntorc::hls::Metric;
use ntorc::layers::LayerKind;
use ntorc::report;

fn main() {
    let mut b = Bencher::new("table1_model_accuracy");
    let pipe = Pipeline::new(PipelineConfig::default());

    let t0 = std::time::Instant::now();
    let db = pipe.synth_database();
    b.record("synth_database/full_sweep", t0.elapsed().as_nanos() as f64);
    println!("database: {} unique (layer, reuse) samples", db.len());

    let t0 = std::time::Instant::now();
    let models = pipe.fit_models(&db);
    b.record("fit_15_forests", t0.elapsed().as_nanos() as f64);

    let (h, rows) = report::table1_rows(&models);
    println!("{}", report::fmt_table("Table I — model validation", &h, &rows));
    report::write_csv("table1_model_accuracy", &h, &rows).expect("csv");

    assert_table1_shape(&models);
    println!("shape checks passed: latency best; LSTM BRAM least predictable");

    b.bench("predict_layer/dense", || {
        models.predict_layer(
            &ntorc::layers::LayerSpec::new(LayerKind::Dense, 512, 64, 1),
            32,
        )
    });
    b.finish();
}

fn assert_table1_shape(models: &CostModels) {
    let get = |k: LayerKind, m: Metric| {
        models
            .validation
            .iter()
            .find(|v| v.kind == k && v.metric == m)
            .expect("validation row")
            .metrics
    };
    // Latency R^2 ~ 0.999 for every kind (paper: 0.9999 / 0.9988 / 0.9931).
    for kind in [LayerKind::Conv1d, LayerKind::Lstm, LayerKind::Dense] {
        let r2 = get(kind, Metric::Latency).r2;
        assert!(r2 > 0.99, "{kind:?} latency r2 {r2}");
    }
    // All metrics strongly predictive (paper Table I: R^2 >= 0.93).
    for v in &models.validation {
        assert!(
            v.metrics.r2 > 0.85,
            "{:?} {:?} r2 {}",
            v.kind,
            v.metric,
            v.metrics.r2
        );
    }
    // LSTM BRAM is the least predictable resource metric (paper: MAPE
    // 11.98 / RMSE 23.37, the worst row).
    let lstm_bram = get(LayerKind::Lstm, Metric::Bram).mape_pct;
    for kind in [LayerKind::Conv1d, LayerKind::Dense] {
        assert!(
            lstm_bram >= get(kind, Metric::Bram).mape_pct,
            "LSTM BRAM should be the noisiest BRAM model"
        );
    }
}
