//! Ablation — latency-budget sensitivity (DESIGN.md design-choice
//! ablation): the paper fixes the real-time constraint at 50,000 cycles
//! (200 µs from DROPBEAR's 5 kHz rate). How does the minimum resource
//! cost move as the budget tightens — where is the feasibility cliff?
//!
//! Claims checked: cost is monotone non-increasing in the budget (more
//! time can never cost more); below the sum of minimum layer latencies
//! the problem is infeasible; the curve flattens once every layer can run
//! at its cheapest reuse factor.

use ntorc::bench::Bencher;
use ntorc::coordinator::PipelineConfig;
use ntorc::report;

fn main() {
    let mut b = Bencher::new("ablation_budget");
    let (pipe, models) = report::standard_models(PipelineConfig::default());

    let headers = vec!["network", "budget_cycles", "budget_us", "cost", "latency", "feasible"];
    let mut rows = Vec::new();
    for (name, net) in report::table4_models() {
        let plan = net.plan();
        let mut prev_cost = f64::INFINITY;
        let mut first_feasible: Option<f64> = None;
        for budget in [2_000.0f64, 5_000.0, 10_000.0, 20_000.0, 50_000.0, 100_000.0, 250_000.0] {
            let prob = models.build_problem(&plan, budget, pipe.cfg.max_choices_per_layer);
            match ntorc::mip::solve_bb(&prob) {
                Some((sol, _)) => {
                    assert!(
                        sol.cost <= prev_cost + 1e-6,
                        "{name}: cost must be monotone in budget ({} @ {budget} vs {prev_cost})",
                        sol.cost
                    );
                    prev_cost = sol.cost;
                    first_feasible.get_or_insert(budget);
                    println!(
                        "{name} @ {budget:>8.0} cycles ({:>6.1} µs): cost {:>9.0}, latency {:>8.0}",
                        budget / 250.0,
                        sol.cost,
                        sol.latency
                    );
                    rows.push(vec![
                        name.to_string(),
                        format!("{budget:.0}"),
                        format!("{:.1}", budget / 250.0),
                        format!("{:.0}", sol.cost),
                        format!("{:.0}", sol.latency),
                        "true".into(),
                    ]);
                }
                None => {
                    assert!(
                        first_feasible.is_none(),
                        "{name}: infeasible at {budget} after feasible at smaller budget"
                    );
                    println!("{name} @ {budget:>8.0} cycles: infeasible");
                    rows.push(vec![
                        name.to_string(),
                        format!("{budget:.0}"),
                        format!("{:.1}", budget / 250.0),
                        String::new(),
                        String::new(),
                        "false".into(),
                    ]);
                }
            }
        }
        // The paper's 50k-cycle point must be comfortably feasible.
        assert!(first_feasible.unwrap_or(f64::INFINITY) <= 50_000.0, "{name} infeasible at 200 µs");
        b.record(
            &format!("first_feasible_budget/{name}"),
            first_feasible.unwrap_or(f64::NAN) * 4.0, // cycles -> ns at 250 MHz
        );
    }
    report::write_csv("ablation_budget", &headers, &rows).expect("csv");
    println!("{}", report::fmt_table("latency-budget ablation", &headers, &rows));
    b.finish();
}
