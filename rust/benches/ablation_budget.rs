//! Ablation — latency-budget sensitivity (DESIGN.md design-choice
//! ablation): the paper fixes the real-time constraint at 50,000 cycles
//! (200 µs from DROPBEAR's 5 kHz rate). How does the minimum resource
//! cost move as the budget tightens — where is the feasibility cliff?
//!
//! Since the frontier engine landed, the whole budget curve comes from
//! ONE dominance-pruned sweep per network (`ParetoFrontier::build` +
//! `FrontierIndex::sweep`) instead of a fresh collapse + B&B per budget;
//! a `solve_bb` cross-check at the paper's 50k-cycle point keeps the
//! fast path honest.
//!
//! Claims checked: cost is monotone non-increasing in the budget (more
//! time can never cost more); below the sum of minimum layer latencies
//! the problem is infeasible; the curve flattens once every layer can run
//! at its cheapest reuse factor.

use ntorc::bench::Bencher;
use ntorc::coordinator::PipelineConfig;
use ntorc::frontier::ParetoFrontier;
use ntorc::report;

fn main() {
    let mut b = Bencher::new("ablation_budget");
    let (pipe, models) = report::standard_models(PipelineConfig::default());

    let headers = vec!["network", "budget_cycles", "budget_us", "cost", "latency", "feasible"];
    let budgets = [2_000.0f64, 5_000.0, 10_000.0, 20_000.0, 50_000.0, 100_000.0, 250_000.0];
    let mut rows = Vec::new();
    for (name, net) in report::table4_models() {
        let plan = net.plan();
        // One collapse + one frontier build serves every budget below.
        let prob = models.build_problem(&plan, 50_000.0, pipe.cfg.max_choices_per_layer);
        let t0 = std::time::Instant::now();
        let index = ParetoFrontier::new(pipe.cfg.workers.max(1)).build(&prob);
        b.record(&format!("frontier_build/{name}"), t0.elapsed().as_nanos() as f64);
        let t0 = std::time::Instant::now();
        let solutions = index.sweep(&budgets);
        b.record(&format!("budget_sweep/{name}"), t0.elapsed().as_nanos() as f64);
        let mut prev_cost = f64::INFINITY;
        let mut first_feasible: Option<f64> = None;
        for (&budget, sol) in budgets.iter().zip(&solutions) {
            match sol {
                Some(sol) => {
                    assert!(
                        sol.cost <= prev_cost + 1e-6,
                        "{name}: cost must be monotone in budget ({} @ {budget} vs {prev_cost})",
                        sol.cost
                    );
                    assert!(sol.latency <= budget + 1e-9, "{name}: budget {budget} violated");
                    prev_cost = sol.cost;
                    first_feasible.get_or_insert(budget);
                    println!(
                        "{name} @ {budget:>8.0} cycles ({:>6.1} µs): cost {:>9.0}, latency {:>8.0}",
                        budget / 250.0,
                        sol.cost,
                        sol.latency
                    );
                    rows.push(vec![
                        name.to_string(),
                        format!("{budget:.0}"),
                        format!("{:.1}", budget / 250.0),
                        format!("{:.0}", sol.cost),
                        format!("{:.0}", sol.latency),
                        "true".into(),
                    ]);
                }
                None => {
                    assert!(
                        first_feasible.is_none(),
                        "{name}: infeasible at {budget} after feasible at smaller budget"
                    );
                    println!("{name} @ {budget:>8.0} cycles: infeasible");
                    rows.push(vec![
                        name.to_string(),
                        format!("{budget:.0}"),
                        format!("{:.1}", budget / 250.0),
                        String::new(),
                        String::new(),
                        "false".into(),
                    ]);
                }
            }
        }
        // B&B fallback cross-check at the paper's operating point (same
        // relative tolerance as FrontierIndex::cross_check_bb).
        let frontier_50k = index.query(50_000.0);
        let bb_50k = ntorc::mip::solve_bb(&prob).map(|(s, _)| s);
        match (&frontier_50k, &bb_50k) {
            (Some(f), Some(bb)) => assert!(
                (f.cost - bb.cost).abs() <= 1e-9 * (1.0 + bb.cost.abs()),
                "{name}: frontier {} disagrees with solve_bb {} at 50k cycles",
                f.cost,
                bb.cost
            ),
            (None, None) => {}
            other => panic!("{name}: feasibility disagreement at 50k cycles: {other:?}"),
        }
        // The paper's 50k-cycle point must be comfortably feasible.
        assert!(first_feasible.unwrap_or(f64::INFINITY) <= 50_000.0, "{name} infeasible at 200 µs");
        b.record(
            &format!("first_feasible_budget/{name}"),
            first_feasible.unwrap_or(f64::NAN) * 4.0, // cycles -> ns at 250 MHz
        );
    }
    report::write_csv("ablation_budget", &headers, &rows).expect("csv");
    println!("{}", report::fmt_table("latency-budget ablation", &headers, &rows));
    b.finish();
}
