//! Bench E1 — Fig 4: regenerate the LUT-cost / latency scaling curves for
//! the three HLS4ML layer datapaths and time the synthesis simulator.

use ntorc::bench::Bencher;
use ntorc::coordinator::{Pipeline, PipelineConfig};
use ntorc::layers::{LayerKind, LayerSpec};
use ntorc::report;

fn main() {
    let mut b = Bencher::new("fig4_scaling");
    let pipe = Pipeline::new(PipelineConfig::default());

    // Regenerate the figure data.
    let (h, rows) = report::fig4_rows(&pipe);
    report::write_csv("fig4_scaling", &h, &rows).expect("csv");
    println!("{}", report::fmt_table("Fig 4 — datapath scaling", &h, &rows));

    // Shape checks mirroring the paper's qualitative claims.
    let latencies = |kind: &str| -> Vec<f64> {
        rows.iter()
            .filter(|r| r[0] == kind)
            .map(|r| r[9].parse::<f64>().unwrap())
            .collect()
    };
    for kind in ["conv1d", "lstm", "dense"] {
        let lat = latencies(kind);
        assert!(
            lat.windows(2).all(|w| w[1] >= w[0] * 0.98),
            "{kind}: latency must rise with reuse"
        );
    }

    // Time the simulator itself (it is inside the DB-generation loop).
    let dense = LayerSpec::new(LayerKind::Dense, 512, 64, 1);
    let lstm = LayerSpec::new(LayerKind::Lstm, 32, 64, 32);
    b.bench("synth_layer/dense_512x64", || pipe.hls.synth_layer(&dense, 16));
    b.bench("synth_layer/lstm_32x64", || pipe.hls.synth_layer(&lstm, 16));
    b.bench("fig4_rows/full_sweep", || report::fig4_rows(&pipe).1.len());
    b.finish();
}
