//! Bench E7 — Fig 8: model prediction vs HLS ground truth on the paper's
//! held-out grids (conv1d (64,16), LSTM (32,16), dense (1,512)), swept
//! over reuse factor × layer size.

use ntorc::bench::Bencher;
use ntorc::coordinator::PipelineConfig;
use ntorc::report;

fn main() {
    let mut b = Bencher::new("fig8_model_vs_truth");
    let t0 = std::time::Instant::now();
    let (pipe, models) = report::standard_models(PipelineConfig::default());
    b.record("standard_models/build", t0.elapsed().as_nanos() as f64);

    let (h, rows) = report::fig8_rows(&pipe, &models);
    println!("{}", report::fmt_table("Fig 8 — prediction vs truth", &h, &rows));
    report::write_csv("fig8_model_vs_truth", &h, &rows).expect("csv");

    // Shape: latency predictions track truth tightly (the paper's right
    // column); resource predictions track within tens of percent.
    let mut lat_err = Vec::new();
    let mut lut_err = Vec::new();
    for r in &rows {
        let lt: f64 = r[5].parse().unwrap();
        let lp: f64 = r[6].parse().unwrap();
        if lt > 0.0 {
            lat_err.push((lp - lt).abs() / lt);
        }
        let ct: f64 = r[3].parse().unwrap();
        let cp: f64 = r[4].parse().unwrap();
        if ct > 0.0 {
            lut_err.push((cp - ct).abs() / ct);
        }
    }
    let med = |v: &mut Vec<f64>| {
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    };
    let (ml, mc) = (med(&mut lat_err), med(&mut lut_err));
    println!("median relative error: latency {:.1}%, LUT {:.1}%", 100.0 * ml, 100.0 * mc);
    assert!(ml < 0.10, "median latency error too high: {ml}");
    assert!(mc < 0.35, "median LUT error too high: {mc}");
    b.finish();
}
