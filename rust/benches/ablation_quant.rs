//! Ablation — quantization co-optimization (the paper's §VIII future
//! work, implemented in `ntorc::quant`): joint (reuse × bit-width)
//! deployment vs the paper's fixed-16-bit deployment, across latency
//! budgets. Claim to verify: allowing narrow widths strictly reduces
//! resource cost at equal latency, with bounded predicted RMSE inflation.

use ntorc::bench::Bencher;
use ntorc::coordinator::candidate_reuse_factors;
use ntorc::hls::HlsSim;
use ntorc::layers::LayerSpec;
use ntorc::quant::{build_quant_problem, solution_rmse_penalty, synth_quantized};
use ntorc::report;

fn main() {
    let mut b = Bencher::new("ablation_quant");
    let sim = HlsSim::default();
    let nets = report::table4_models();

    let headers = vec![
        "network", "budget_cycles", "mode", "cost", "latency", "rmse_penalty", "bits_used",
    ];
    let mut rows: Vec<Vec<String>> = Vec::new();
    for (name, net) in &nets {
        let plan = net.plan();
        for budget in [20_000.0f64, 50_000.0] {
            // Fixed 16-bit (the paper's setting).
            let predict16 = |spec: &LayerSpec, r: usize, bits: u32| {
                let c = synth_quantized(&sim, spec, r, bits);
                (c.resource_sum(), c.latency)
            };
            let (prob16, _q16) = build_quant_problem(
                &plan,
                budget,
                0.0, // zero accuracy budget => only 16-bit choices
                predict16,
                |s| candidate_reuse_factors(s, 24),
            );
            // Joint optimization with a modest accuracy allowance.
            let (prob_joint, qj) = build_quant_problem(
                &plan,
                budget,
                0.02, // per-layer predicted RMSE allowance
                predict16,
                |s| candidate_reuse_factors(s, 24),
            );
            let s16 = ntorc::mip::solve_bb(&prob16);
            let sj = ntorc::mip::solve_bb(&prob_joint);
            let (Some((s16, _)), Some((sj, _))) = (s16, sj) else {
                println!("{name} @ {budget}: infeasible, skipping");
                continue;
            };
            let bits_used: Vec<u32> = sj
                .pick
                .iter()
                .enumerate()
                .map(|(i, &j)| qj[i][j].bits)
                .collect();
            let pen = solution_rmse_penalty(&qj, &sj.pick);
            rows.push(vec![
                name.to_string(),
                format!("{budget:.0}"),
                "fixed16".into(),
                format!("{:.0}", s16.cost),
                format!("{:.0}", s16.latency),
                "0".into(),
                "16".into(),
            ]);
            rows.push(vec![
                name.to_string(),
                format!("{budget:.0}"),
                "joint".into(),
                format!("{:.0}", sj.cost),
                format!("{:.0}", sj.latency),
                format!("{pen:.4}"),
                format!("{bits_used:?}").replace(',', ";"),
            ]);
            // The ablation claim.
            assert!(
                sj.cost <= s16.cost + 1e-9,
                "{name} @ {budget}: joint ({}) worse than fixed16 ({})",
                sj.cost,
                s16.cost
            );
            println!(
                "{name} @ {budget:.0} cycles: fixed16 cost {:.0} -> joint {:.0} ({:.1}% saved, \
                 predicted RMSE +{pen:.4})",
                s16.cost,
                sj.cost,
                100.0 * (1.0 - sj.cost / s16.cost)
            );
        }
    }
    report::write_csv("ablation_quant", &headers, &rows).expect("csv");
    println!("{}", report::fmt_table("quantization ablation", &headers, &rows));

    // Time the joint solve (choice sets are ~4x larger).
    let plan = nets[0].1.plan();
    b.bench("joint_quant_solve/model1", || {
        let (prob, _) = build_quant_problem(
            &plan,
            50_000.0,
            0.05,
            |spec, r, bits| {
                let c = synth_quantized(&sim, spec, r, bits);
                (c.resource_sum(), c.latency)
            },
            |s| candidate_reuse_factors(s, 16),
        );
        ntorc::mip::solve_bb(&prob).is_some()
    });
    b.finish();
}
