//! Bench E2 — Fig 5: the multi-objective hyperparameter search producing
//! the (RMSE, workload) Pareto front, with the prior-work reference
//! points retrained on the same data. NTORC_BENCH_FAST=1 shrinks trials.

use ntorc::bench::Bencher;
use ntorc::coordinator::{Pipeline, PipelineConfig};
use ntorc::hpo::{hypervolume_2d, pareto_trials};
use ntorc::report;

fn main() {
    let mut b = Bencher::new("fig5_pareto");
    let fast = std::env::var("NTORC_BENCH_FAST").is_ok();
    let mut cfg = PipelineConfig::smoke();
    cfg.hpo.n_trials = if fast { 10 } else { 28 };
    cfg.hpo.n_init = if fast { 4 } else { 8 };
    cfg.budget.steps = if fast { 60 } else { 160 };
    cfg.hpo.space = ntorc::hpo::SearchSpace::default();
    let pipe = Pipeline::new(cfg);
    let sim = report::standard_simulator();

    let t0 = std::time::Instant::now();
    let out = report::fig5_run(&pipe, &sim);
    b.record("hpo_run/total", t0.elapsed().as_nanos() as f64);

    let front = pareto_trials(&out.trials);
    let pts: Vec<(f64, f64)> = front
        .iter()
        .map(|t| (t.rmse, (t.workload + 1.0).ln()))
        .collect();
    let hv = hypervolume_2d(&pts, (1.0, 25.0));
    println!(
        "{} trials, front size {}, hypervolume {:.3}",
        out.trials.len(),
        front.len(),
        hv
    );
    assert!(front.len() >= 2, "degenerate front");
    // Front must be properly ordered: cheaper ⇒ less accurate.
    for w in front.windows(2) {
        assert!(w[0].rmse >= w[1].rmse && w[0].workload <= w[1].workload);
    }

    let (h, rows) = report::fig5_rows(&out);
    println!("{}", report::fmt_table("Fig 5 — Pareto front", &h, &rows));
    report::write_csv("fig5_pareto", &h, &rows).expect("csv");
    b.finish();
}
