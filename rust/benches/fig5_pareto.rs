//! Bench E2 — Fig 5: the multi-objective hyperparameter search producing
//! the (RMSE, workload) Pareto front, with the prior-work reference
//! points retrained on the same data; the accuracy front is then pushed
//! through a frontier-served deployment sweep (one solver frontier per
//! trial answers every latency budget). NTORC_BENCH_FAST=1 shrinks
//! trials.

use ntorc::bench::Bencher;
use ntorc::coordinator::{Pipeline, PipelineConfig};
use ntorc::hpo::{hypervolume_2d, pareto_trials};
use ntorc::report;

fn main() {
    let mut b = Bencher::new("fig5_pareto");
    let fast = std::env::var("NTORC_BENCH_FAST").is_ok();
    let mut cfg = PipelineConfig::smoke();
    cfg.hpo.n_trials = if fast { 10 } else { 28 };
    cfg.hpo.n_init = if fast { 4 } else { 8 };
    cfg.budget.steps = if fast { 60 } else { 160 };
    cfg.hpo.space = ntorc::hpo::SearchSpace::default();
    let pipe = Pipeline::new(cfg);
    let sim = pipe.workload();

    let t0 = std::time::Instant::now();
    let out = report::fig5_run(&pipe, &sim);
    b.record("hpo_run/total", t0.elapsed().as_nanos() as f64);

    let front = pareto_trials(&out.trials);
    let pts: Vec<(f64, f64)> = front
        .iter()
        .map(|t| (t.rmse, (t.workload + 1.0).ln()))
        .collect();
    let hv = hypervolume_2d(&pts, (1.0, 25.0));
    println!(
        "{} trials, front size {}, hypervolume {:.3}",
        out.trials.len(),
        front.len(),
        hv
    );
    assert!(front.len() >= 2, "degenerate front");
    // Front must be properly ordered: cheaper ⇒ less accurate.
    for w in front.windows(2) {
        assert!(w[0].rmse >= w[1].rmse && w[0].workload <= w[1].workload);
    }

    let (h, rows) = report::fig5_rows(&out);
    println!("{}", report::fmt_table("Fig 5 — Pareto front", &h, &rows));
    report::write_csv("fig5_pareto", &h, &rows).expect("csv");

    // Deployment leg: the most accurate front member, deployed at a grid
    // of real-time budgets from one shared solver frontier instead of a
    // fresh MIP per constraint.
    let db = pipe.synth_database();
    let models = pipe.fit_models(&db);
    let best = front
        .iter()
        .min_by(|a, b| a.rmse.partial_cmp(&b.rmse).unwrap())
        .expect("non-empty front");
    let budgets = [10_000.0, 25_000.0, 50_000.0, 100_000.0, 250_000.0];
    let t0 = std::time::Instant::now();
    let deployed = pipe.deploy_sweep(&models, best, &budgets);
    b.record("deploy_sweep/5_budgets", t0.elapsed().as_nanos() as f64);
    let mut prev_cost = f64::INFINITY;
    let mut n_feasible = 0usize;
    for (budget, d) in budgets.iter().zip(&deployed) {
        if let Some(d) = d {
            n_feasible += 1;
            assert!(d.solution.latency <= budget + 1e-6, "budget {budget} violated");
            assert!(d.solution.cost <= prev_cost + 1e-9, "cost must be monotone in the budget");
            prev_cost = d.solution.cost;
            println!(
                "deploy @ {budget:>8.0} cycles: cost {:>9.0}, latency {:>8.0}, reuse {:?}",
                d.solution.cost, d.solution.latency, d.reuse
            );
        } else {
            println!("deploy @ {budget:>8.0} cycles: infeasible");
        }
    }
    assert!(n_feasible >= 1, "the 200 µs point must be deployable");
    b.finish();
}
