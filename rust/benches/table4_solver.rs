//! Bench E8 — Table IV: N-TORC's MIP vs stochastic search vs simulated
//! annealing on the two 11-layer target networks. The paper's headline:
//! the baselines need ~1M trials (1000× the MIP's time) to match it.
//!
//! NTORC_BENCH_FAST=1 drops the 1M-trial points.

use ntorc::bench::Bencher;
use ntorc::coordinator::PipelineConfig;
use ntorc::report;

fn main() {
    let mut b = Bencher::new("table4_solver");
    let fast = std::env::var("NTORC_BENCH_FAST").is_ok();
    // 100K is the largest default: the baselines scale linearly (the
    // paper's 1M point is 10x the 100K time; `ntorc table4
    // --trials 1000000` reproduces it when you have the minutes).
    let trial_counts: Vec<usize> = if fast {
        vec![1_000, 10_000]
    } else {
        vec![1_000, 10_000, 100_000]
    };

    let t0 = std::time::Instant::now();
    let (pipe, models) = report::standard_models(PipelineConfig::default());
    b.record("standard_models/build", t0.elapsed().as_nanos() as f64);

    let mut all = Vec::new();
    for (name, net) in report::table4_models() {
        let prob = models.build_problem(
            &net.plan(),
            pipe.cfg.latency_budget,
            pipe.cfg.max_choices_per_layer,
        );
        println!("{name}: {:.3e} RF permutations", prob.permutations());
        let rows = report::table4_run(&pipe, &models, name, &net, &trial_counts, 0x7AB4E4);

        let mip = rows.iter().find(|r| r.solver == "ntorc_mip").expect("mip");
        b.record(&format!("mip_solve/{name}"), mip.seconds * 1e9);
        // Quality: the MIP must be at least as cheap as every baseline at
        // every trial count (it is exact).
        for r in rows.iter().filter(|r| r.solver != "ntorc_mip") {
            // The MIP's candidate set is log-thinned (48/layer), so allow
            // a sliver of slack vs baselines sampling ALL divisors.
            assert!(
                mip.luts + mip.dsps <= (r.luts + r.dsps) * 1.02,
                "{}: MIP ({:.0}) worse than {} @ {} ({:.0})",
                name,
                mip.luts + mip.dsps,
                r.solver,
                r.trials,
                r.luts + r.dsps
            );
            assert!(mip.latency_us <= 200.0 + 1e-6);
        }
        // Timing: the largest baseline run is orders of magnitude slower.
        if let Some(big) = rows
            .iter()
            .filter(|r| r.solver == "stochastic")
            .max_by_key(|r| r.trials)
        {
            let speedup = big.seconds / mip.seconds.max(1e-9);
            println!(
                "{name}: MIP {:.4}s vs stochastic@{} {:.3}s => {:.0}x",
                mip.seconds, big.trials, big.seconds, speedup
            );
        }
        all.extend(rows);
    }
    let (h, rows) = report::table4_rows(&all);
    println!("{}", report::fmt_table("Table IV — solver comparison", &h, &rows));
    report::write_csv("table4_solver", &h, &rows).expect("csv");
    b.finish();
}
