//! Bench E8 — Table IV: N-TORC's exact solvers vs stochastic search vs
//! simulated annealing on the two 11-layer target networks. The paper's
//! headline: the baselines need ~1M trials (1000× the MIP's time) to
//! match it. This bench additionally measures the frontier engine: one
//! dominance-pruned sweep answers *every* latency budget, and its total
//! time (build + all queries) must beat the per-constraint `solve_bb`
//! re-solves it replaces.
//!
//! NTORC_BENCH_FAST=1 drops the 100K-trial points.

use ntorc::bench::Bencher;
use ntorc::coordinator::PipelineConfig;
use ntorc::report;

fn main() {
    let mut b = Bencher::new("table4_solver");
    let fast = std::env::var("NTORC_BENCH_FAST").is_ok();
    // 100K is the largest default: the baselines scale linearly (the
    // paper's 1M point is 10x the 100K time; `ntorc table4
    // --trials 1000000` reproduces it when you have the minutes).
    let trial_counts: Vec<usize> = if fast {
        vec![1_000, 10_000]
    } else {
        vec![1_000, 10_000, 100_000]
    };

    let t0 = std::time::Instant::now();
    let (pipe, models) = report::standard_models(PipelineConfig::default());
    b.record("standard_models/build", t0.elapsed().as_nanos() as f64);

    let mut all = Vec::new();
    let mut sweeps = Vec::new();
    for (name, net) in report::table4_models() {
        let prob = models.build_problem(
            &net.plan(),
            pipe.cfg.latency_budget,
            pipe.cfg.max_choices_per_layer,
        );
        println!("{name}: {:.3e} RF permutations", prob.permutations());
        let rows = report::table4_run(&pipe, &models, name, &net, &trial_counts, 0x7AB4E4);

        let mip = rows.iter().find(|r| r.solver == "ntorc_mip").expect("mip");
        let frontier = rows
            .iter()
            .find(|r| r.solver == "ntorc_frontier")
            .expect("frontier");
        b.record(&format!("mip_solve/{name}"), mip.seconds * 1e9);
        b.record(&format!("frontier_solve/{name}"), frontier.seconds * 1e9);
        // Quality: both exact paths must be at least as cheap as every
        // baseline at every trial count.
        for r in rows.iter().filter(|r| !r.solver.starts_with("ntorc")) {
            for exact in [mip, frontier] {
                // The exact candidate set is log-thinned (48/layer), so
                // allow a sliver of slack vs baselines sampling ALL
                // divisors.
                assert!(
                    exact.luts + exact.dsps <= (r.luts + r.dsps) * 1.02,
                    "{}: {} ({:.0}) worse than {} @ {} ({:.0})",
                    name,
                    exact.solver,
                    exact.luts + exact.dsps,
                    r.solver,
                    r.trials,
                    r.luts + r.dsps
                );
                assert!(exact.latency_us <= 200.0 + 1e-6);
            }
        }
        // Timing: the largest baseline run is orders of magnitude slower.
        if let Some(big) = rows
            .iter()
            .filter(|r| r.solver == "stochastic")
            .max_by_key(|r| r.trials)
        {
            let speedup = big.seconds / mip.seconds.max(1e-9);
            println!(
                "{name}: MIP {:.4}s vs stochastic@{} {:.3}s => {:.0}x",
                mip.seconds, big.trials, big.seconds, speedup
            );
        }
        all.extend(rows);

        // Frontier sweep: one build answers the whole budget grid, with
        // the per-budget B&B path timed and cross-checked against it.
        let sw = report::frontier_sweep_run(&pipe, &models, name, &net, &report::SWEEP_BUDGETS);
        let frontier_total = sw.build_seconds + sw.query_seconds;
        println!(
            "{name}: frontier sweep over {} budgets: build {:.4}s + queries {:.6}s = {:.4}s \
             vs per-constraint B&B {:.4}s ({} nodes) => {:.1}x",
            sw.budgets.len(),
            sw.build_seconds,
            sw.query_seconds,
            frontier_total,
            sw.bb_seconds_total,
            sw.bb_nodes_total,
            sw.bb_seconds_total / frontier_total.max(1e-9)
        );
        b.record(&format!("frontier_build/{name}"), sw.build_seconds * 1e9);
        b.record(&format!("frontier_sweep_queries/{name}"), sw.query_seconds * 1e9);
        b.record(&format!("bb_per_budget_total/{name}"), sw.bb_seconds_total * 1e9);
        // The PR's acceptance bar: the frontier-sweep total time must
        // beat the sum of the per-constraint solve_bb times it replaces.
        assert!(
            frontier_total < sw.bb_seconds_total,
            "{name}: frontier sweep {frontier_total}s not faster than {} per-budget B&B solves \
             ({}s)",
            sw.budgets.len(),
            sw.bb_seconds_total
        );
        sweeps.push(sw);
    }
    let (h, rows) = report::table4_rows(&all);
    println!("{}", report::fmt_table("Table IV — solver comparison", &h, &rows));
    report::write_csv("table4_solver", &h, &rows).expect("csv");
    let (sh, srows) = report::frontier_sweep_rows(&sweeps);
    println!(
        "{}",
        report::fmt_table("Frontier — one sweep, every latency budget", &sh, &srows)
    );
    report::write_csv("table4_frontier_sweep", &sh, &srows).expect("csv");
    b.finish();
}
