//! Ablation — HPO sampler choice (DESIGN.md design-choice ablation):
//! the paper uses BoTorch's Bayesian multi-objective sampler; how much
//! does it buy over random search and NSGA-II at equal trial budgets?
//! Metric: Pareto hypervolume of (RMSE, log workload).

use ntorc::bench::Bencher;
use ntorc::coordinator::{Pipeline, PipelineConfig};
use ntorc::hpo::{hypervolume_2d, pareto_trials, Sampler};
use ntorc::report;

fn main() {
    let mut b = Bencher::new("ablation_sampler");
    let fast = std::env::var("NTORC_BENCH_FAST").is_ok();
    let sim = report::standard_workload("dropbear");

    let headers = vec!["sampler", "trials", "front_size", "hypervolume", "best_rmse", "seconds"];
    let mut rows = Vec::new();
    for sampler in [Sampler::Random, Sampler::Bayes, Sampler::Nsga2] {
        let mut cfg = PipelineConfig::smoke();
        cfg.hpo.sampler = sampler;
        cfg.hpo.n_trials = if fast { 10 } else { 24 };
        cfg.hpo.n_init = 6;
        cfg.budget.steps = if fast { 40 } else { 120 };
        cfg.hpo.space = ntorc::hpo::SearchSpace::default();
        let pipe = Pipeline::new(cfg);
        let t0 = std::time::Instant::now();
        let (trials, _) = pipe.run_hpo(&sim);
        let secs = t0.elapsed().as_secs_f64();
        let front = pareto_trials(&trials);
        let pts: Vec<(f64, f64)> = front
            .iter()
            .map(|t| (t.rmse, (t.workload + 1.0).ln()))
            .collect();
        let hv = hypervolume_2d(&pts, (1.0, 25.0));
        let best = front.last().map(|t| t.rmse).unwrap_or(f64::NAN);
        println!(
            "{sampler:?}: {} trials, front {}, HV {:.3}, best RMSE {:.4}, {:.1}s",
            trials.len(),
            front.len(),
            hv,
            best,
            secs
        );
        rows.push(vec![
            format!("{sampler:?}"),
            trials.len().to_string(),
            front.len().to_string(),
            format!("{hv:.4}"),
            format!("{best:.4}"),
            format!("{secs:.2}"),
        ]);
        b.record(&format!("hpo/{sampler:?}"), secs * 1e9);
    }
    report::write_csv("ablation_sampler", &headers, &rows).expect("csv");
    println!("{}", report::fmt_table("sampler ablation", &headers, &rows));
    b.finish();
}
