//! The HTTP front-end contract over real sockets: an ephemeral-port
//! [`ntorc::httpd::Server`] driven by the crate's own
//! [`ntorc::loadgen::HttpClient`]. Covers the ISSUE's four scenarios —
//! cold query → warm re-query (builds stay at 1), malformed body →
//! structured `bad_request` envelope, saturation → `429` +
//! `Retry-After` (with the warm-bypass exception), and a graceful drain
//! that completes in-flight requests and flushes the stats file
//! atomically.

use std::sync::Arc;
use std::time::Duration;

use ntorc::httpd::{HttpConfig, NamedNets, ProblemSource, Server};
use ntorc::layers::NetConfig;
use ntorc::loadgen::{ClientError, HttpClient};
use ntorc::mip::{Choice, DeployProblem};
use ntorc::ser::{parse_json, Json};
use ntorc::serve::{FrontierService, FrontierStore, ServeConfig};

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        capacity: 8,
        workers: 1,
        max_choices_per_layer: 16,
        latency_budget: 50_000.0,
        max_points: None,
        epsilon: None,
        point_budget: None,
        latency_gamma: None,
        fifo_cost_per_slot: None,
        fifo_min_depth: 0.0,
        workload: None,
        backend: None,
    }
}

fn tiny_net() -> NetConfig {
    NetConfig::new(16, vec![], vec![], vec![4, 1])
}

fn named() -> NamedNets {
    Arc::new(|name: &str| (name == "tiny").then(tiny_net))
}

/// Deterministic toy problems (same net → same problem), optionally
/// slowed down so a build is observably "in flight".
fn toy_builder(delay_ms: u64) -> Arc<dyn Fn(&NetConfig) -> DeployProblem + Send + Sync> {
    Arc::new(move |net: &NetConfig| {
        if delay_ms > 0 {
            std::thread::sleep(Duration::from_millis(delay_ms));
        }
        let layers = (0..net.plan().len().max(1))
            .map(|k| {
                (0..4)
                    .map(|j| Choice {
                        reuse: 1 << j,
                        cost: 500.0 / (j + 1) as f64 + k as f64,
                        latency: (8 * (j + 1)) as f64,
                    })
                    .collect()
            })
            .collect();
        DeployProblem { layers, latency_budget: 0.0, fifo: None }
    })
}

fn http_cfg(threads: usize, permits: usize) -> HttpConfig {
    HttpConfig {
        addr: "127.0.0.1:0".to_string(),
        threads,
        max_inflight_builds: permits,
        drain_timeout_ms: 2_000,
    }
}

fn start(
    http: HttpConfig,
    store: Option<FrontierStore>,
    delay_ms: u64,
    stats_path: Option<std::path::PathBuf>,
) -> Server {
    let svc = Arc::new(FrontierService::new(serve_cfg(), store));
    Server::start(
        http,
        svc,
        ProblemSource::Builder(toy_builder(delay_ms)),
        named(),
        stats_path,
    )
    .expect("server starts on an ephemeral port")
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("ntorc_httpd_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn builds_of(stats_body: &Json) -> f64 {
    stats_body
        .get("ok")
        .and_then(|ok| ok.get("stats"))
        .and_then(|s| s.get("builds"))
        .expect("stats carry builds")
        .as_f64()
        .unwrap()
}

fn error_code_of(body: &Json) -> String {
    body.get("error")
        .and_then(|e| e.get("code"))
        .expect("error envelope carries a code")
        .as_str()
        .unwrap()
        .to_string()
}

#[test]
fn cold_query_then_warm_requery_over_the_wire() {
    let server = start(http_cfg(2, 2), None, 0, None);
    let addr = server.addr().to_string();
    let mut client = HttpClient::new(addr);

    let health = client.get("/healthz").unwrap();
    assert_eq!(health.status, 200);

    // Cold: versioned envelope, one build.
    let body = r#"{"v": 1, "requests": [{"network": "tiny", "budget": 100}]}"#;
    let reply = client.post("/v1/query", body).unwrap();
    assert_eq!(reply.status, 200, "{}", reply.body);
    let doc = reply.json().unwrap();
    assert_eq!(doc.get("v").unwrap().as_f64(), Some(1.0));
    let ok = doc.get("ok").unwrap();
    assert_eq!(ok.get("count").unwrap().as_f64(), Some(1.0));
    let results = ok.get("results").unwrap().as_arr().unwrap();
    assert!(results[0].get("feasible").unwrap().as_bool().unwrap());
    assert!(!results[0].get("reuse_factors").unwrap().as_arr().unwrap().is_empty());

    // Warm re-query on the SAME keep-alive connection: builds stay 1.
    let reply = client.post("/v1/query", body).unwrap();
    assert_eq!(reply.status, 200);
    let stats = client.get("/v1/stats").unwrap();
    assert_eq!(stats.status, 200);
    let sdoc = stats.json().unwrap();
    assert_eq!(builds_of(&sdoc), 1.0, "second query must be served warm");

    // Legacy un-versioned body keeps parsing (treated as v1).
    let legacy = r#"[{"network": "tiny", "budget": 100}]"#;
    assert_eq!(client.post("/v1/query", legacy).unwrap().status, 200);

    // Structured errors: malformed JSON, bad version, unknown network,
    // wrong method, unknown route.
    let bad = client.post("/v1/query", "this is not json").unwrap();
    assert_eq!(bad.status, 400);
    assert_eq!(error_code_of(&bad.json().unwrap()), "bad_request");
    let v9 = client
        .post("/v1/query", r#"{"v": 9, "requests": [{"network": "tiny", "budget": 1}]}"#)
        .unwrap();
    assert_eq!(v9.status, 400);
    assert_eq!(error_code_of(&v9.json().unwrap()), "bad_request");
    let unknown = client
        .post("/v1/query", r#"{"requests": [{"network": "nope", "budget": 1}]}"#)
        .unwrap();
    assert_eq!(unknown.status, 404);
    assert_eq!(error_code_of(&unknown.json().unwrap()), "unknown_network");
    let method = client.request("GET", "/v1/query", None).unwrap();
    assert_eq!(method.status, 405);
    assert_eq!(error_code_of(&method.json().unwrap()), "method_not_allowed");
    let route = client.get("/nope").unwrap();
    assert_eq!(route.status, 404);
    assert_eq!(error_code_of(&route.json().unwrap()), "not_found");

    let down = client.post("/v1/shutdown", "{}").unwrap();
    assert_eq!(down.status, 200);
    let (served, _rejected) = server.join().unwrap();
    assert!(served >= 3, "three successful query batches were served, got {served}");
}

#[test]
fn backend_assertion_is_enforced_on_the_wire() {
    use ntorc::serve::BackendKey;
    // A systolic-scoped server: /v1/stats names the active backend,
    // matching assertions are answered, and a mismatched assertion is
    // a 409 with the frozen unknown_backend code.
    let svc = Arc::new(FrontierService::new(
        ServeConfig { backend: Some(BackendKey { name: "systolic".into() }), ..serve_cfg() },
        None,
    ));
    let server =
        Server::start(http_cfg(2, 2), svc, ProblemSource::Builder(toy_builder(0)), named(), None)
            .expect("server starts on an ephemeral port");
    let mut client = HttpClient::new(server.addr().to_string());
    let stats = client.get("/v1/stats").unwrap().json().unwrap();
    assert_eq!(
        stats.get("ok").and_then(|o| o.get("backend")).expect("stats name a backend").as_str(),
        Some("systolic")
    );
    let matching =
        r#"{"v": 1, "backend": "systolic", "requests": [{"network": "tiny", "budget": 100}]}"#;
    assert_eq!(client.post("/v1/query", matching).unwrap().status, 200);
    let wrong =
        r#"{"v": 1, "backend": "hls4ml", "requests": [{"network": "tiny", "budget": 100}]}"#;
    let reply = client.post("/v1/query", wrong).unwrap();
    assert_eq!(reply.status, 409);
    assert_eq!(error_code_of(&reply.json().unwrap()), "unknown_backend");
    client.post("/v1/shutdown", "{}").unwrap();
    server.join().unwrap();

    // An unscoped server answers for the hls4ml default: asserting it
    // succeeds, anything else is refused.
    let server = start(http_cfg(2, 2), None, 0, None);
    let mut client = HttpClient::new(server.addr().to_string());
    let stats = client.get("/v1/stats").unwrap().json().unwrap();
    assert_eq!(
        stats.get("ok").and_then(|o| o.get("backend")).expect("stats name a backend").as_str(),
        Some("hls4ml")
    );
    let default_ok =
        r#"{"v": 1, "backend": "hls4ml", "requests": [{"network": "tiny", "budget": 100}]}"#;
    assert_eq!(client.post("/v1/query", default_ok).unwrap().status, 200);
    let other =
        r#"{"v": 1, "backend": "systolic", "requests": [{"network": "tiny", "budget": 100}]}"#;
    let reply = client.post("/v1/query", other).unwrap();
    assert_eq!(reply.status, 409);
    assert_eq!(error_code_of(&reply.json().unwrap()), "unknown_backend");
    client.post("/v1/shutdown", "{}").unwrap();
    server.join().unwrap();
}

#[test]
fn saturation_returns_429_and_warm_requests_bypass_the_gate() {
    // Zero build permits: every cold batch is refused deterministically.
    let dir = temp_dir("saturation");
    let server = start(http_cfg(2, 0), Some(FrontierStore::new(&dir)), 0, None);
    let addr = server.addr().to_string();
    let mut client = HttpClient::new(addr);
    let body = r#"{"v": 1, "requests": [{"network": "tiny", "budget": 100}]}"#;
    let reply = client.post("/v1/query", body).unwrap();
    assert_eq!(reply.status, 429);
    assert_eq!(error_code_of(&reply.json().unwrap()), "overloaded");
    assert_eq!(
        reply.headers.get("retry-after").map(|s| s.as_str()),
        Some("1"),
        "429 must carry Retry-After"
    );
    client.post("/v1/shutdown", "{}").unwrap();
    server.join().unwrap();

    // Warm the store out of band, then restart with zero permits: the
    // same request now bypasses the gate entirely (warm traffic can
    // never be 429'd).
    let warmer = start(http_cfg(2, 1), Some(FrontierStore::new(&dir)), 0, None);
    let mut client = HttpClient::new(warmer.addr().to_string());
    assert_eq!(client.post("/v1/query", body).unwrap().status, 200);
    client.post("/v1/shutdown", "{}").unwrap();
    warmer.join().unwrap();

    let gated = start(http_cfg(2, 0), Some(FrontierStore::new(&dir)), 0, None);
    let mut client = HttpClient::new(gated.addr().to_string());
    let warm = client.post("/v1/query", body).unwrap();
    assert_eq!(warm.status, 200, "warm request must bypass the build gate: {}", warm.body);
    client.post("/v1/shutdown", "{}").unwrap();
    gated.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn trace_header_round_trips_and_slow_builds_log_a_span_tree() {
    let dir = temp_dir("obs");
    std::fs::create_dir_all(&dir).unwrap();
    let log_path = dir.join("obs.jsonl");
    // obs on, slow_ms far below the 200 ms builder delay: the cold
    // build below is guaranteed to be logged as "slow". (The obs
    // globals are process-wide; concurrent tests may add lines to this
    // log, but they carry different trace IDs.)
    ntorc::obs::init(&ntorc::obs::ObsConfig {
        enabled: true,
        log_path: log_path.to_string_lossy().into_owned(),
        sample: 0.0,
        slow_ms: 50,
    })
    .unwrap();
    let server = start(http_cfg(2, 2), None, 200, None);
    let mut client = HttpClient::new(server.addr().to_string());
    let body = r#"{"v": 1, "requests": [{"network": "tiny", "budget": 100}]}"#;

    // Client-chosen trace ID round-trips into the response envelope.
    let reply = client.post_traced("/v1/query", body, "it-trace-cold-1").unwrap();
    assert_eq!(reply.status, 200, "{}", reply.body);
    let doc = reply.json().unwrap();
    assert_eq!(
        doc.get("trace").unwrap().as_str(),
        Some("it-trace-cold-1"),
        "X-Ntorc-Trace must echo as the envelope's trace field"
    );

    // No header: the server generates a distinct ID.
    let doc = client.post("/v1/query", body).unwrap().json().unwrap();
    let generated = doc.get("trace").unwrap().as_str().unwrap().to_string();
    assert!(!generated.is_empty() && generated != "it-trace-cold-1");

    // /v1/metrics: plain-text Prometheus exposition with frozen names.
    let metrics = client.get("/v1/metrics").unwrap();
    assert_eq!(metrics.status, 200);
    assert!(
        metrics
            .headers
            .get("content-type")
            .is_some_and(|ct| ct.starts_with("text/plain")),
        "metrics are text, not JSON"
    );
    for name in ["ntorc_requests_total", "ntorc_serve_builds_total", "ntorc_request_ns_bucket"] {
        assert!(metrics.body.contains(name), "exposition missing {name}");
    }
    assert_eq!(
        client.request("POST", "/v1/metrics", Some("{}")).unwrap().status,
        405,
        "metrics endpoint is GET-only"
    );

    client.post("/v1/shutdown", "{}").unwrap();
    server.join().unwrap();
    ntorc::obs::init(&ntorc::obs::ObsConfig::default()).unwrap();

    // The slow cold request logged one JSONL line whose span tree
    // attributes the time to named stages, down to the DP levels.
    let text = std::fs::read_to_string(&log_path).expect("event log written");
    let line = text
        .lines()
        .find(|l| l.contains("it-trace-cold-1"))
        .expect("slow request logged by trace ID");
    let doc = parse_json(line).unwrap();
    assert_eq!(doc.get("level").unwrap().as_str(), Some("slow"));
    assert_eq!(doc.get("slow").unwrap().as_bool(), Some(true));
    assert_eq!(doc.get("path").unwrap().as_str(), Some("/v1/query"));
    let spans = doc.get("spans").unwrap().as_arr().unwrap();
    let names: Vec<String> = spans
        .iter()
        .map(|s| s.get("name").unwrap().as_str().unwrap().to_string())
        .collect();
    for want in ["parse", "admission", "collapse", "build", "build/level0", "query", "encode"] {
        assert!(names.iter().any(|n| n == want), "span tree missing '{want}': {names:?}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn graceful_drain_completes_in_flight_requests_and_flushes_stats() {
    let dir = temp_dir("drain");
    std::fs::create_dir_all(&dir).unwrap();
    let stats_path = dir.join("serve_stats.json");
    // Slow builder (300 ms): the drain lands while a build is in flight.
    let server = start(http_cfg(3, 2), None, 300, Some(stats_path.clone()));
    let addr = server.addr().to_string();

    let slow_addr = addr.clone();
    let in_flight = std::thread::spawn(move || {
        let mut client = HttpClient::new(slow_addr);
        client.post(
            "/v1/query",
            r#"{"v": 1, "requests": [{"network": "tiny", "budget": 100}]}"#,
        )
    });
    std::thread::sleep(Duration::from_millis(100));
    let mut client = HttpClient::new(addr.clone());
    assert_eq!(client.post("/v1/shutdown", "{}").unwrap().status, 200);

    // The in-flight request completes with a full 200 despite the drain.
    let reply = in_flight.join().unwrap().expect("in-flight request must not be dropped");
    assert_eq!(reply.status, 200, "{}", reply.body);

    let (served, _rejected) = server.join().unwrap();
    assert!(served >= 1, "the in-flight request counts as served");
    // join() flushed the stats snapshot atomically: the file exists,
    // parses, and no tmp litter remains.
    let text = std::fs::read_to_string(&stats_path).expect("stats file flushed on drain");
    let doc = parse_json(&text).expect("stats file is valid JSON");
    assert!(doc.get("stats").and_then(|s| s.get("builds")).is_ok());
    let litter: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
        .collect();
    assert!(litter.is_empty(), "atomic flush must not leave tmp files");

    // The drained server is gone: fresh connections are refused, which
    // the client classifies as rejected (never "lost").
    let mut after = HttpClient::new(addr);
    match after.get("/healthz") {
        Err(ClientError::Unreachable(_)) => {}
        Ok(r) => panic!("drained server still answering: {}", r.status),
        Err(e) => panic!("expected clean refusal, got {e}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}
