//! Integration: the full coordinator pipeline (HLS DB -> cost models ->
//! HPO -> MIP deployment) at smoke scale, plus cross-module invariants
//! that no single unit test can see.

use ntorc::coordinator::{
    prepare_data, Pipeline, PipelineConfig, TrainBudget, LATENCY_BUDGET_CYCLES,
};
use ntorc::hpo::{pareto_trials, Sampler};
use ntorc::layers::NetConfig;
use ntorc::report;
use ntorc::testkit::prop_check;

fn smoke_pipe() -> Pipeline {
    Pipeline::new(PipelineConfig::smoke())
}

#[test]
fn database_to_models_to_deployment() {
    let pipe = smoke_pipe();
    let db = pipe.synth_database();
    assert!(db.len() > 100);
    let models = pipe.fit_models(&db);
    // Deploy a hand-picked network through the full path.
    let net = NetConfig::new(64, vec![(3, 8)], vec![8], vec![16, 1]);
    let trial = ntorc::hpo::Trial {
        genome: vec![0; ntorc::hpo::SearchSpace::GENES],
        cfg: net.clone(),
        rmse: 0.1,
        workload: net.workload_multiplies() as f64,
    };
    let deployed = pipe.deploy(&models, &trial).expect("deployable");
    // The real-time contract.
    assert!(deployed.latency_us <= 200.0 + 1e-6);
    assert_eq!(deployed.reuse.len(), net.plan().len());
    // Every chosen reuse factor divides its layer's GEMV product.
    for (spec, &r) in net.plan().iter().zip(&deployed.reuse) {
        assert_eq!((spec.n_in * spec.n_out) % r, 0, "invalid reuse {r}");
    }
    // Predicted latency within 25% of the simulator's ground truth at the
    // same assignment (the models were trained on this simulator).
    let rel = (deployed.predicted.latency - deployed.actual.latency).abs()
        / deployed.actual.latency.max(1.0);
    assert!(rel < 0.25, "latency prediction error {rel}");
}

#[test]
fn hpo_front_shrinks_with_budget() {
    // The Pareto front must trade off: min-workload trial has the max
    // RMSE among front members and vice versa.
    let mut cfg = PipelineConfig::smoke();
    cfg.hpo.n_trials = 10;
    let pipe = Pipeline::new(cfg);
    let sim = report::standard_workload("dropbear");
    let (trials, _) = pipe.run_hpo(&sim);
    assert!(trials.len() >= 8);
    let front = pareto_trials(&trials);
    assert!(!front.is_empty());
    for w in front.windows(2) {
        assert!(w[0].rmse >= w[1].rmse);
        assert!(w[0].workload <= w[1].workload);
    }
}

#[test]
fn samplers_explore_the_same_space() {
    // Every sampler must produce valid, in-space configurations.
    for sampler in [Sampler::Random, Sampler::Bayes, Sampler::Nsga2] {
        let mut cfg = PipelineConfig::smoke();
        cfg.hpo.sampler = sampler;
        cfg.hpo.n_trials = 6;
        cfg.budget = TrainBudget { steps: 10, ..TrainBudget::smoke() };
        let pipe = Pipeline::new(cfg);
        let sim = report::standard_workload("dropbear");
        let (trials, _) = pipe.run_hpo(&sim);
        assert!(trials.len() >= 5, "{sampler:?} produced {}", trials.len());
        for t in &trials {
            assert!(t.cfg.is_valid());
            assert!(t.rmse.is_finite() && t.rmse > 0.0);
            assert_eq!(t.workload, t.cfg.workload_multiplies() as f64);
        }
    }
}

#[test]
fn prepared_data_respects_protocol() {
    let sim = report::standard_workload("dropbear");
    let dc = ntorc::coordinator::DataConfig::smoke();
    let prepared = prepare_data(&sim, &dc, 32);
    assert!(!prepared.train.is_empty());
    assert!(!prepared.val.is_empty());
    assert!(!prepared.test.is_empty());
    // 70/30 split within 10% tolerance.
    let frac = prepared.val.len() as f64
        / (prepared.train.len() + prepared.val.len()) as f64;
    assert!((frac - 0.3).abs() < 0.1, "val fraction {frac}");
    // Targets are normalized to [0,1].
    for &y in prepared.train.y.iter().take(500) {
        assert!((-0.01..=1.01).contains(&y));
    }
}

#[test]
fn property_deployments_always_meet_budget() {
    // Across random small networks, any returned deployment satisfies the
    // latency constraint and uses valid reuse factors.
    let pipe = smoke_pipe();
    let db = pipe.synth_database();
    let models = pipe.fit_models(&db);
    let space = ntorc::hpo::SearchSpace::small();
    prop_check("deployments-meet-budget", 15, |g| {
        let genome = (0..ntorc::hpo::SearchSpace::GENES)
            .map(|i| g.int(0, space.gene_card(i) - 1))
            .collect::<Vec<_>>();
        let net = space.decode(&genome);
        let trial = ntorc::hpo::Trial {
            genome,
            cfg: net.clone(),
            rmse: 0.1,
            workload: net.workload_multiplies() as f64,
        };
        match pipe.deploy(&models, &trial) {
            None => Ok(()), // infeasible is a legal outcome
            Some(d) => {
                if d.solution.latency > LATENCY_BUDGET_CYCLES + 1e-6 {
                    return Err(format!("budget violated: {}", d.solution.latency));
                }
                for (spec, &r) in net.plan().iter().zip(&d.reuse) {
                    if (spec.n_in * spec.n_out) % r != 0 {
                        return Err(format!("invalid reuse {r} for {spec:?}"));
                    }
                }
                Ok(())
            }
        }
    });
}
