//! Cross-workload invariants (the PR's acceptance bar): the three
//! scenario families behind the `Workload` trait must (1) produce
//! distinct frontier-store keys for identical layer plans — zero
//! cross-workload cache hits over a shared store, (2) generate
//! bit-identical datasets for a fixed seed at any worker count, and
//! (3) derive sorted, positive latency-budget grids from their sample
//! rates. A fourth scenario added to the registry inherits every test
//! here for free.

use std::sync::Arc;

use ntorc::coordinator::{Pipeline, PipelineConfig};
use ntorc::layers::NetConfig;
use ntorc::mip::{Choice, DeployProblem};
use ntorc::rng::Rng;
use ntorc::serve::{FrontierService, FrontierStore, ServeConfig, WorkloadKey};
use ntorc::workload::{self, Workload, BUDGET_FRACTIONS};

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("ntorc_wlmx_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Cheap workload instances (small DROPBEAR frequency table — the
/// default 96-point eigen-solve is build-once-per-command, not
/// per-test).
fn cheap_workloads() -> Vec<Arc<dyn Workload>> {
    vec![
        Arc::new(ntorc::dropbear::Simulator::new(ntorc::dropbear::SimConfig {
            table_points: 12,
            ..Default::default()
        })),
        Arc::new(ntorc::rotor::RotorSim::new(ntorc::rotor::RotorConfig::default())),
        Arc::new(ntorc::battery::BatterySim::new(
            ntorc::battery::BatteryConfig::default(),
        )),
    ]
}

/// Deterministic toy deployment problem (no cost models needed).
fn toy_problem(tag: u64) -> DeployProblem {
    let mut rng = Rng::new(0x3012AD ^ tag);
    let layers = (0..3)
        .map(|_| {
            (0..4)
                .map(|j| Choice {
                    reuse: 1 << j,
                    cost: 500.0 / (j + 1) as f64 + rng.range_f64(0.0, 20.0),
                    latency: (8 * (j + 1)) as f64 + rng.range_f64(0.0, 3.0).floor(),
                })
                .collect()
        })
        .collect();
    DeployProblem { layers, latency_budget: 0.0, fifo: None }
}

#[test]
fn budget_grids_are_sorted_positive_and_derived_from_sample_rate() {
    for w in cheap_workloads() {
        let grid = w.budget_grid();
        assert_eq!(grid.len(), BUDGET_FRACTIONS.len());
        let deadline = workload::deadline_cycles_for(w.sample_rate_hz());
        assert_eq!(w.deadline_cycles(), deadline);
        for (b, frac) in grid.iter().zip(BUDGET_FRACTIONS) {
            assert!(*b > 0.0, "{}: non-positive budget {b}", w.name());
            assert_eq!(*b, (frac * deadline).round(), "{}: grid not derived", w.name());
        }
        for pair in grid.windows(2) {
            assert!(pair[0] < pair[1], "{}: grid not sorted", w.name());
        }
        // The real-time point (fraction 1.0) is on the grid.
        assert!(grid.contains(&deadline.round()), "{}: deadline missing", w.name());
    }
}

#[test]
fn dataset_generation_is_bit_identical_across_worker_counts() {
    for w in cheap_workloads() {
        let sequential = w.generate_dataset(0.15, 0.02, 77);
        for workers in [1usize, 2, 4] {
            let parallel =
                workload::generate_dataset_parallel(&w, 0.15, 0.02, 77, workers);
            assert_eq!(sequential.len(), parallel.len(), "{}", w.name());
            for (a, b) in sequential.iter().zip(&parallel) {
                assert_eq!(a.profile, b.profile, "{}", w.name());
                assert_eq!(a.seed, b.seed, "{}", w.name());
                assert_eq!(a.input, b.input, "{}: input drifted", w.name());
                assert_eq!(a.target, b.target, "{}: target drifted", w.name());
            }
        }
    }
}

#[test]
fn workloads_never_collide_in_a_shared_store() {
    // Three services over ONE store directory, identical layer plan,
    // only the workload identity differs: three distinct keys, three
    // builds, three documents — and re-resolution hits only the own
    // workload's cache (zero cross-workload hits).
    let dir = temp_dir("shared_store");
    let net = NetConfig::new(32, vec![(3, 4)], vec![], vec![8, 1]);
    let mk = |name: &str, rate: f64| {
        FrontierService::new(
            ServeConfig {
                workload: Some(WorkloadKey { name: name.into(), sample_rate_hz: rate }),
                ..ServeConfig::default()
            },
            Some(FrontierStore::new(&dir)),
        )
    };
    let services: Vec<(FrontierService, u64)> = workload::ALL
        .into_iter()
        .enumerate()
        .map(|(i, name)| {
            (mk(name, workload::sample_rate_of(name).unwrap()), i as u64)
        })
        .collect();
    let keys: Vec<_> = services.iter().map(|(s, _)| s.key_for(&net)).collect();
    for i in 0..keys.len() {
        for j in i + 1..keys.len() {
            assert_ne!(keys[i].hash, keys[j].hash, "workload keys collided");
        }
        assert!(
            keys[i].name.starts_with(workload::ALL[i]),
            "slug {} should carry its workload prefix",
            keys[i].name
        );
    }
    // Cold pass: every workload must build its own frontier despite the
    // shared directory already holding the others' documents.
    for (svc, tag) in &services {
        svc.resolve_with(svc.key_for(&net), || toy_problem(*tag));
        let s = svc.stats.snapshot();
        assert_eq!((s.builds, s.store_hits), (1, 0), "cross-workload store hit");
    }
    assert_eq!(FrontierStore::new(&dir).list().len(), workload::ALL.len());
    // Warm pass: each service hits only its own entry.
    for (svc, _) in &services {
        svc.resolve_with(svc.key_for(&net), || unreachable!("must be cached"));
        let s = svc.stats.snapshot();
        assert_eq!((s.builds, s.mem_hits), (1, 1));
    }
    // Fresh services per workload over the same store: store hits only,
    // and each loads a frontier built from its own (distinct) problem.
    for (i, name) in workload::ALL.into_iter().enumerate() {
        let fresh = mk(name, workload::sample_rate_of(name).unwrap());
        let served = fresh.resolve_with(fresh.key_for(&net), || {
            unreachable!("store must answer")
        });
        let s = fresh.stats.snapshot();
        assert_eq!((s.builds, s.store_hits), (0, 1), "{name}");
        // The loaded document matches this workload's own problem.
        let expect = ntorc::frontier::ParetoFrontier::new(1).build(&toy_problem(i as u64));
        assert_eq!(served.index.len(), expect.len(), "{name}: wrong document served");
        for k in 0..expect.len() {
            assert_eq!(served.index.point(k), expect.point(k), "{name}: point {k}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn eps_mode_never_collides_with_exact_in_a_shared_store() {
    // The ε axis composes with the workload axis: one store directory,
    // one layer plan, every (workload, ε-mode) pair gets its own key,
    // its own build and its own document — zero cross-mode hits in
    // either direction, even warm.
    let dir = temp_dir("eps_store");
    let net = NetConfig::new(32, vec![(3, 4)], vec![], vec![8, 1]);
    let mk = |name: &str, epsilon: Option<f64>| {
        FrontierService::new(
            ServeConfig {
                epsilon,
                workload: Some(WorkloadKey {
                    name: name.into(),
                    sample_rate_hz: workload::sample_rate_of(name).unwrap(),
                }),
                ..ServeConfig::default()
            },
            Some(FrontierStore::new(&dir)),
        )
    };
    let services: Vec<(FrontierService, u64)> = workload::ALL
        .into_iter()
        .enumerate()
        .flat_map(|(i, name)| {
            [
                (mk(name, None), i as u64),
                (mk(name, Some(0.05)), 8 + i as u64),
            ]
        })
        .collect();
    // All six keys distinct; ε keys carry the eps- slug inside the
    // workload prefix.
    let keys: Vec<_> = services.iter().map(|(s, _)| s.key_for(&net)).collect();
    for i in 0..keys.len() {
        for j in i + 1..keys.len() {
            assert_ne!(keys[i].hash, keys[j].hash, "key collision at {i},{j}");
        }
    }
    for (i, name) in workload::ALL.into_iter().enumerate() {
        assert!(!keys[2 * i].name.contains("eps-"));
        assert!(keys[2 * i + 1].name.starts_with(&format!("{name}-eps-")));
    }
    // Cold pass: every (workload, mode) builds its own frontier despite
    // the shared directory filling up around it.
    for (svc, tag) in &services {
        svc.resolve_with(svc.key_for(&net), || toy_problem(*tag));
        let s = svc.stats.snapshot();
        assert_eq!((s.builds, s.store_hits), (1, 0), "cross-mode store hit");
    }
    assert_eq!(FrontierStore::new(&dir).list().len(), services.len());
    // Warm pass from fresh services: each loads only its own document.
    for (i, name) in workload::ALL.into_iter().enumerate() {
        for (epsilon, tag) in [(None, i as u64), (Some(0.05), 8 + i as u64)] {
            let fresh = mk(name, epsilon);
            let served = fresh.resolve_with(fresh.key_for(&net), || {
                unreachable!("store must answer")
            });
            let s = fresh.stats.snapshot();
            assert_eq!((s.builds, s.store_hits), (0, 1), "{name} eps={epsilon:?}");
            // The served document is the one built from this pair's own
            // problem, in this pair's own mode.
            assert_eq!(served.index.stats.epsilon, epsilon.unwrap_or(0.0));
            let expect = ntorc::frontier::ParetoFrontier::new(1)
                .with_epsilon(epsilon)
                .build(&toy_problem(tag));
            assert_eq!(served.index.len(), expect.len(), "{name}: wrong document");
            for k in 0..expect.len() {
                assert_eq!(served.index.point(k), expect.point(k), "{name}: point {k}");
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pipelines_scope_frontier_keys_by_workload() {
    // The end-to-end wiring: two pipelines differing only in workload
    // file the same architecture under different keys.
    let mut a = PipelineConfig::smoke();
    a.set_workload("rotor").unwrap();
    let mut b = PipelineConfig::smoke();
    b.set_workload("battery").unwrap();
    // Equalize the budget so the ONLY difference is the workload id
    // (the budget is not part of the key anyway, but be explicit).
    b.latency_budget = a.latency_budget;
    let net = NetConfig::new(32, vec![(3, 4)], vec![], vec![8, 1]);
    let ka = Pipeline::new(a).serve().key_for(&net);
    let kb = Pipeline::new(b).serve().key_for(&net);
    assert_ne!(ka.hash, kb.hash);
    assert!(ka.name.starts_with("rotor-"));
    assert!(kb.name.starts_with("battery-"));
}
