//! Cross-backend invariants (the PR's acceptance bar): every hardware
//! cost target behind the `Backend` trait must (1) mint distinct
//! frontier-store keys for identical layer plans — zero cross-backend
//! cache hits over a shared store — while the default hls4ml backend
//! keeps minting the exact pre-backend keys (existing warm stores never
//! rebuild), (2) compose with the workload and ε axes as one more
//! independent key dimension, and (3) thread through the pipeline so a
//! `--backend systolic` run files its frontiers under backend-scoped
//! slugs. A third backend added to the registry inherits every test
//! here for free.

use ntorc::backend;
use ntorc::coordinator::{Pipeline, PipelineConfig};
use ntorc::layers::NetConfig;
use ntorc::mip::{Choice, DeployProblem};
use ntorc::rng::Rng;
use ntorc::serve::{BackendKey, FrontierService, FrontierStore, ServeConfig, WorkloadKey};
use ntorc::workload;

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("ntorc_bemx_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Deterministic toy deployment problem (no cost models needed).
fn toy_problem(tag: u64) -> DeployProblem {
    let mut rng = Rng::new(0x3012AD ^ tag);
    let layers = (0..3)
        .map(|_| {
            (0..4)
                .map(|j| Choice {
                    reuse: 1 << j,
                    cost: 500.0 / (j + 1) as f64 + rng.range_f64(0.0, 20.0),
                    latency: (8 * (j + 1)) as f64 + rng.range_f64(0.0, 3.0).floor(),
                })
                .collect()
        })
        .collect();
    DeployProblem { layers, latency_budget: 0.0, fifo: None }
}

#[test]
fn backends_never_collide_in_a_shared_store() {
    // One store directory, one layer plan, only the backend identity
    // differs: distinct keys, one build and one document per backend —
    // and re-resolution hits only the own backend's cache.
    let dir = temp_dir("shared_store");
    let net = NetConfig::new(32, vec![(3, 4)], vec![], vec![8, 1]);
    let mk = |name: &str| {
        FrontierService::new(
            ServeConfig {
                backend: Some(BackendKey { name: name.into() }),
                ..ServeConfig::default()
            },
            Some(FrontierStore::new(&dir)),
        )
    };
    let services: Vec<(FrontierService, u64)> = backend::ALL
        .into_iter()
        .enumerate()
        .map(|(i, name)| (mk(name), i as u64))
        .collect();
    let keys: Vec<_> = services.iter().map(|(s, _)| s.key_for(&net)).collect();
    for i in 0..keys.len() {
        for j in i + 1..keys.len() {
            assert_ne!(keys[i].hash, keys[j].hash, "backend keys collided");
        }
    }
    // The default backend IS the pre-backend identity: its key is
    // bit-identical to a backend-agnostic service's (no slug prefix),
    // so existing store documents stay warm across the upgrade. Every
    // other backend carries its name as the outermost slug prefix.
    let agnostic = FrontierService::new(ServeConfig::default(), None);
    for ((svc, _), name) in services.iter().zip(backend::ALL) {
        if name == backend::DEFAULT {
            assert_eq!(svc.config().backend, None, "default must normalize away");
            assert_eq!(svc.key_for(&net), agnostic.key_for(&net));
        } else {
            assert!(
                svc.key_for(&net).name.starts_with(&format!("{name}-")),
                "slug {} should carry its backend prefix",
                svc.key_for(&net).name
            );
        }
    }
    // Cold pass: every backend must build its own frontier despite the
    // shared directory already holding the others' documents.
    for (svc, tag) in &services {
        svc.resolve_with(svc.key_for(&net), || toy_problem(*tag));
        let s = svc.stats.snapshot();
        assert_eq!((s.builds, s.store_hits), (1, 0), "cross-backend store hit");
    }
    assert_eq!(FrontierStore::new(&dir).list().len(), backend::ALL.len());
    // Warm pass: each service hits only its own LRU entry.
    for (svc, _) in &services {
        svc.resolve_with(svc.key_for(&net), || unreachable!("must be cached"));
        let s = svc.stats.snapshot();
        assert_eq!((s.builds, s.mem_hits), (1, 1));
    }
    // Fresh services per backend over the same store: store hits only,
    // and each loads the frontier built from its own problem.
    for (i, name) in backend::ALL.into_iter().enumerate() {
        let fresh = mk(name);
        let served = fresh.resolve_with(fresh.key_for(&net), || {
            unreachable!("store must answer")
        });
        let s = fresh.stats.snapshot();
        assert_eq!((s.builds, s.store_hits), (0, 1), "{name}");
        let expect = ntorc::frontier::ParetoFrontier::new(1).build(&toy_problem(i as u64));
        assert_eq!(served.index.len(), expect.len(), "{name}: wrong document served");
        for k in 0..expect.len() {
            assert_eq!(served.index.point(k), expect.point(k), "{name}: point {k}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn backend_axis_composes_with_workload_and_eps() {
    // The backend axis is one more independent key dimension: every
    // (backend, workload, ε-mode) combination over one store directory
    // gets its own key, its own build and its own document, with the
    // slug nesting backend-<workload>-eps-<arch> outermost-first.
    let dir = temp_dir("axes");
    let net = NetConfig::new(32, vec![(3, 4)], vec![], vec![8, 1]);
    let mk = |be: &str, wl: &str, epsilon: Option<f64>| {
        FrontierService::new(
            ServeConfig {
                epsilon,
                workload: Some(WorkloadKey {
                    name: wl.into(),
                    sample_rate_hz: workload::sample_rate_of(wl).unwrap(),
                }),
                backend: Some(BackendKey { name: be.into() }),
                ..ServeConfig::default()
            },
            Some(FrontierStore::new(&dir)),
        )
    };
    let mut services = Vec::new();
    let mut tag = 0u64;
    for be in backend::ALL {
        for wl in ["rotor", "battery"] {
            for epsilon in [None, Some(0.05)] {
                services.push((mk(be, wl, epsilon), tag, be, wl, epsilon));
                tag += 1;
            }
        }
    }
    let keys: Vec<_> = services.iter().map(|(s, ..)| s.key_for(&net)).collect();
    for i in 0..keys.len() {
        for j in i + 1..keys.len() {
            assert_ne!(keys[i].hash, keys[j].hash, "key collision at {i},{j}");
        }
    }
    for ((_, _, be, wl, epsilon), key) in services.iter().zip(&keys) {
        let eps_slug = if epsilon.is_some() { "eps-" } else { "" };
        let want = if *be == backend::DEFAULT {
            format!("{wl}-{eps_slug}")
        } else {
            format!("{be}-{wl}-{eps_slug}")
        };
        assert!(key.name.starts_with(&want), "slug {} !~ {want}", key.name);
    }
    // Cold pass: every combination builds its own frontier despite the
    // shared directory filling up around it; then the store holds one
    // document per combination and fresh services only load their own.
    for (svc, tag, ..) in &services {
        svc.resolve_with(svc.key_for(&net), || toy_problem(*tag));
        let s = svc.stats.snapshot();
        assert_eq!((s.builds, s.store_hits), (1, 0), "cross-axis store hit");
    }
    assert_eq!(FrontierStore::new(&dir).list().len(), services.len());
    for (_, tag, be, wl, epsilon) in &services {
        let fresh = mk(be, wl, *epsilon);
        let served = fresh.resolve_with(fresh.key_for(&net), || {
            unreachable!("store must answer")
        });
        let s = fresh.stats.snapshot();
        assert_eq!((s.builds, s.store_hits), (0, 1), "{be}/{wl}/eps={epsilon:?}");
        let expect = ntorc::frontier::ParetoFrontier::new(1)
            .with_epsilon(*epsilon)
            .build(&toy_problem(*tag));
        assert_eq!(served.index.len(), expect.len(), "{be}/{wl}: wrong document");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pipelines_scope_frontier_keys_by_backend() {
    // The end-to-end wiring: two pipelines differing only in backend
    // file the same architecture under different keys, the systolic one
    // with the backend as the outermost slug segment — and the hls4ml
    // pipeline's key is exactly the pre-backend (workload-only) key.
    let mut a = PipelineConfig::smoke();
    a.set_workload("rotor").unwrap();
    let mut b = PipelineConfig::smoke();
    b.set_workload("rotor").unwrap();
    b.set_backend("systolic").unwrap();
    let net = NetConfig::new(32, vec![(3, 4)], vec![], vec![8, 1]);
    let ka = Pipeline::new(a).serve().key_for(&net);
    let kb = Pipeline::new(b).serve().key_for(&net);
    assert_ne!(ka.hash, kb.hash);
    assert!(ka.name.starts_with("rotor-"), "default backend leaves slugs unchanged");
    assert!(kb.name.starts_with("systolic-rotor-"));
}
