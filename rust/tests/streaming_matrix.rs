//! Streaming-mode key scoping (the PR's acceptance bar): the FIFO /
//! adaptive-ε / latency-γ serving knobs must (1) mint distinct frontier
//! keys per mode — zero cross-mode hits over one shared store, (2)
//! leave keys bit-identical to the pre-streaming release whenever every
//! knob is off (including knobs set to their normalized-off values),
//! and (3) carry deep catalog plans through the same key/serve/store
//! machinery as the shallow Table IV models.

use ntorc::layers::NetConfig;
use ntorc::mip::{Choice, DeployProblem};
use ntorc::rng::Rng;
use ntorc::serve::{FrontierService, FrontierStore, ServeConfig};

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("ntorc_stmx_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Deterministic toy deployment problem (no cost models needed).
fn toy_problem(tag: u64) -> DeployProblem {
    let mut rng = Rng::new(0x57AE_A0 ^ tag);
    let layers = (0..3)
        .map(|_| {
            (0..4)
                .map(|j| Choice {
                    reuse: 1 << j,
                    cost: 500.0 / (j + 1) as f64 + rng.range_f64(0.0, 20.0),
                    latency: (8 * (j + 1)) as f64 + rng.range_f64(0.0, 3.0).floor(),
                })
                .collect()
        })
        .collect();
    DeployProblem { layers, latency_budget: 0.0, fifo: None }
}

fn shallow_net() -> NetConfig {
    NetConfig::new(32, vec![(3, 4)], vec![], vec![8, 1])
}

/// One service per streaming mode, all sharing `dir`.
fn mode_services(dir: &std::path::Path) -> Vec<(&'static str, FrontierService)> {
    let mk = |cfg: ServeConfig| FrontierService::new(cfg, Some(FrontierStore::new(dir)));
    vec![
        ("plain", mk(ServeConfig::default())),
        (
            "fifo",
            mk(ServeConfig { fifo_cost_per_slot: Some(0.5), ..ServeConfig::default() }),
        ),
        (
            "fifo-deep",
            // Same per-slot cost, different min depth: still distinct.
            mk(ServeConfig {
                fifo_cost_per_slot: Some(0.5),
                fifo_min_depth: 2.0,
                ..ServeConfig::default()
            }),
        ),
        (
            "adaptive",
            mk(ServeConfig { point_budget: Some(64), ..ServeConfig::default() }),
        ),
        (
            "gamma",
            mk(ServeConfig { latency_gamma: Some(0.1), ..ServeConfig::default() }),
        ),
    ]
}

#[test]
fn streaming_modes_never_collide_in_a_shared_store() {
    let dir = temp_dir("shared");
    let net = shallow_net();
    let services = mode_services(&dir);
    let keys: Vec<_> = services.iter().map(|(_, s)| s.key_for(&net)).collect();
    for i in 0..keys.len() {
        for j in i + 1..keys.len() {
            assert_ne!(
                keys[i].hash, keys[j].hash,
                "{} / {} keys collided",
                services[i].0, services[j].0
            );
        }
    }
    // Readable slugs: each mode carries its prefix, plain carries none.
    assert!(!keys[0].name.contains("fifo-"));
    assert!(!keys[0].name.contains("pb-"));
    assert!(!keys[0].name.contains("gam-"));
    assert!(keys[1].name.starts_with("fifo-"));
    assert!(keys[2].name.starts_with("fifo-"));
    assert!(keys[3].name.starts_with("pb-"));
    assert!(keys[4].name.starts_with("gam-"));
    // Cold pass: every mode builds its own frontier despite the shared
    // directory filling up around it — zero cross-mode store hits.
    for (i, (name, svc)) in services.iter().enumerate() {
        svc.resolve_with(svc.key_for(&net), || toy_problem(i as u64));
        let s = svc.stats.snapshot();
        assert_eq!((s.builds, s.store_hits), (1, 0), "{name}: cross-mode store hit");
    }
    assert_eq!(FrontierStore::new(&dir).list().len(), services.len());
    // Fresh services per mode over the same store: store hits only, and
    // each loads the document built from its own problem.
    for (i, (name, _)) in mode_services(&dir).into_iter().enumerate() {
        let fresh = mode_services(&dir).remove(i).1;
        let served = fresh.resolve_with(fresh.key_for(&net), || {
            unreachable!("store must answer")
        });
        let s = fresh.stats.snapshot();
        assert_eq!((s.builds, s.store_hits), (0, 1), "{name}");
        let expect = ntorc::frontier::ParetoFrontier::new(1).build(&toy_problem(i as u64));
        assert_eq!(served.index.len(), expect.len(), "{name}: wrong document served");
        for k in 0..expect.len() {
            assert_eq!(served.index.point(k), expect.point(k), "{name}: point {k}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn knobs_at_their_off_values_keep_pre_streaming_keys() {
    // The byte-compat pin: a config whose streaming knobs are all at
    // their normalized-off values mints EXACTLY the default key — same
    // hash, same slug — so shallow-plan stores written before the
    // streaming release stay warm. (The absolute PR 9 hash itself is
    // pinned by serve::tests::key_hash_is_pinned.)
    for net in [shallow_net(), NetConfig::new(64, vec![], vec![8], vec![16, 1])] {
        let plain = FrontierService::new(ServeConfig::default(), None);
        let off = FrontierService::new(
            ServeConfig {
                point_budget: None,
                latency_gamma: Some(0.0), // normalizes to None
                fifo_cost_per_slot: Some(-1.0), // normalizes to None
                fifo_min_depth: 3.0, // irrelevant without fifo pricing
                ..ServeConfig::default()
            },
            None,
        );
        assert_eq!(plain.key_for(&net), off.key_for(&net));
    }
}

#[test]
fn fifo_widths_follow_the_plan_and_deep_plans_flow_through_serving() {
    let svc = FrontierService::new(
        ServeConfig { fifo_cost_per_slot: Some(0.25), fifo_min_depth: 1.5, ..ServeConfig::default() },
        None,
    );
    // Per-boundary widths are the producing layer's output feature dim.
    let net = shallow_net();
    let plan = net.plan();
    let fifo = svc.fifo_model_for(&plan).expect("pricing is on");
    assert_eq!(fifo.widths.len(), plan.len() - 1);
    for (w, l) in fifo.widths.iter().zip(&plan) {
        assert_eq!(*w, l.n_out as f64);
    }
    assert_eq!(fifo.cost_per_slot, 0.25);
    assert_eq!(fifo.min_depth, 1.5);
    // Single-layer plans have no boundary to price.
    let single = NetConfig::new(16, vec![], vec![], vec![1]);
    assert!(svc.fifo_model_for(&single.plan()).is_none());

    // A deep catalog plan (transformer lowering, 18 deployed layers)
    // keys and serves exactly like the shallow models: its own distinct
    // key per mode, resolved and cached through the same store.
    let deep = NetConfig::transformer(64, 16, 4);
    assert_eq!(deep.plan().len(), 18);
    let dir = temp_dir("deep");
    for cfg in [
        ServeConfig::default(),
        ServeConfig { fifo_cost_per_slot: Some(0.5), ..ServeConfig::default() },
    ] {
        let svc = FrontierService::new(cfg, Some(FrontierStore::new(&dir)));
        assert_ne!(svc.key_for(&deep).hash, svc.key_for(&shallow_net()).hash);
        svc.resolve_with(svc.key_for(&deep), || toy_problem(99));
        let s = svc.stats.snapshot();
        assert_eq!(s.builds, 1);
        // Warm within the same service.
        svc.resolve_with(svc.key_for(&deep), || unreachable!("must be cached"));
        assert_eq!(svc.stats.snapshot().mem_hits, 1);
    }
    // Two documents: the FIFO-mode deep frontier never shadowed the
    // plain one.
    assert_eq!(FrontierStore::new(&dir).list().len(), 2);
    let _ = std::fs::remove_dir_all(&dir);
}
