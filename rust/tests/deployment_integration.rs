//! Integration: solver cross-checks at deployment scale — the exact MIP,
//! the Pareto-frontier engine, the DP oracle and both baselines on
//! realistic cost models (not the synthetic instances of the unit
//! tests).

use ntorc::coordinator::{Pipeline, PipelineConfig};
use ntorc::frontier::ParetoFrontier;
use ntorc::report;
use ntorc::search::{simulated_annealing, stochastic_search, SaConfig};

fn realistic_problem() -> (Pipeline, ntorc::mip::DeployProblem) {
    let pipe = Pipeline::new(PipelineConfig::smoke());
    let db = pipe.synth_database();
    let models = pipe.fit_models(&db);
    let net = report::table4_models()[1].1.clone(); // conv+lstm+dense mix
    let prob = models.build_problem(&net.plan(), 50_000.0, 24);
    (pipe, prob)
}

#[test]
fn bb_matches_dp_on_realistic_models() {
    let (_pipe, prob) = realistic_problem();
    let bb = ntorc::mip::solve_bb(&prob);
    let dp = ntorc::mip::solve_dp(&prob);
    match (bb, dp) {
        (Some((b, stats)), Some(d)) => {
            assert!(
                (b.cost - d.cost).abs() < 1e-6 * (1.0 + d.cost),
                "bb {} vs dp {}",
                b.cost,
                d.cost
            );
            assert!(stats.nodes >= 1);
        }
        (None, None) => {}
        other => panic!("feasibility disagreement: {:?}", other.0.map(|x| x.0.cost)),
    }
}

#[test]
fn frontier_matches_bb_across_budgets_on_realistic_models() {
    // The frontier engine on a real collapsed knapsack (conv+lstm+dense
    // mix, 24 choices/layer): every budget on a wide grid must agree
    // with a fresh B&B solve, and the index must be a clean staircase.
    let (_pipe, prob) = realistic_problem();
    let index = ParetoFrontier::new(2).build(&prob);
    index.check_invariants().expect("frontier invariants");
    assert!(index.len() >= 2, "realistic problems trade cost for latency");
    // A handful of B&B re-solves: each is a full branch-and-bound in
    // debug mode, so keep the grid small here (the release-mode benches
    // sweep far more budgets).
    let budgets = vec![15_000.0, 30_000.0, 50_000.0, 80_000.0, 120_000.0, 200_000.0];
    let stats = index
        .cross_check_bb(&prob, &budgets)
        .expect("frontier must agree with solve_bb at every budget");
    println!(
        "frontier: {} points; replaced B&B work: {} nodes / {} LP solves over {} budgets",
        index.len(),
        stats.nodes,
        stats.lp_solves,
        budgets.len()
    );
    // Worker count must not change the frontier.
    let serial = ParetoFrontier::new(1).build(&prob);
    assert_eq!(serial.len(), index.len());
    for i in 0..serial.len() {
        assert_eq!(serial.point(i), index.point(i));
        assert_eq!(serial.pick(i), index.pick(i));
    }
}

#[test]
fn baselines_converge_toward_mip_quality() {
    let (_pipe, prob) = realistic_problem();
    let (opt, _) = ntorc::mip::solve_bb(&prob).expect("feasible");
    let small = stochastic_search(&prob, 1_000, 11);
    let large = stochastic_search(&prob, 50_000, 11);
    let sa = simulated_annealing(&prob, 50_000, SaConfig::default(), 13);
    // Table IV shape: more trials close the gap; none beat the exact MIP.
    if let (Some(s), Some(l)) = (&small.best, &large.best) {
        assert!(l.cost <= s.cost + 1e-9);
        assert!(opt.cost <= l.cost + 1e-6);
        let gap_small = s.cost / opt.cost;
        let gap_large = l.cost / opt.cost;
        assert!(gap_large <= gap_small + 1e-9);
        println!("gap: 1K {gap_small:.3} -> 50K {gap_large:.3}");
    }
    if let Some(s) = &sa.best {
        assert!(opt.cost <= s.cost + 1e-6);
        assert!(s.latency <= prob.latency_budget + 1e-9);
    }
}

#[test]
fn mip_is_orders_of_magnitude_faster_than_equivalent_search() {
    // The paper's 1000x claim, scaled down. The baselines pay a full
    // random-forest inference per trial (the paper's §VI-C cost
    // structure); N-TORC collapses the forests once and solves exactly.
    let pipe = Pipeline::new(PipelineConfig::smoke());
    let db = pipe.synth_database();
    let models = pipe.fit_models(&db);
    let net = report::table4_models()[1].1.clone();
    let rows = report::table4_run(&pipe, &models, "m2", &net, &[10_000], 17);
    let mip = rows.iter().find(|r| r.solver == "ntorc_mip").expect("mip row");
    let st = rows.iter().find(|r| r.solver == "stochastic").expect("st row");
    println!(
        "mip {:.4}s vs stochastic@10K {:.3}s (x{:.0})",
        mip.seconds,
        st.seconds,
        st.seconds / mip.seconds.max(1e-9)
    );
    // Quality: exact solver at least matches the baseline.
    assert!(mip.luts + mip.dsps <= (st.luts + st.dsps) * 1.02);
    assert!(mip.latency_us <= 200.0 + 1e-6);
    // Timing: at least 5x faster than even this modest 10K-trial run
    // (the full-scale bench shows the paper's ~1000x at 1M trials).
    assert!(
        st.seconds > 5.0 * mip.seconds,
        "mip {}s vs search {}s",
        mip.seconds,
        st.seconds
    );
}
