//! Integration: the python-AOT -> rust-PJRT round trip, and the numeric
//! agreement between the PJRT-executed artifacts and the native Rust
//! training substrate. Requires `make artifacts`.

use ntorc::layers::NetConfig;
use ntorc::nn::NativeModel;
use ntorc::rng::Rng;
use ntorc::runtime::Runtime;
use ntorc::tensor::Tensor;

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("quickstart.meta.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(dir).expect("PJRT CPU client"))
}

#[test]
fn artifacts_discovered() {
    let Some(rt) = runtime() else { return };
    let names = rt.available_models().unwrap();
    assert!(names.contains(&"quickstart".to_string()), "{names:?}");
    assert!(names.contains(&"model1".to_string()));
    assert!(names.contains(&"model2".to_string()));
}

#[test]
fn manifest_matches_rust_layer_walk() {
    // The manifest's workload must equal the Rust-side formula — the two
    // layer walks (python model.py / rust layers.rs) stay in lockstep.
    let Some(rt) = runtime() else { return };
    for name in rt.available_models().unwrap() {
        let model = rt.load(&name).unwrap();
        let cfg: &NetConfig = &model.meta.cfg;
        assert_eq!(
            cfg.workload_multiplies(),
            model.meta.workload_multiplies,
            "workload mismatch for {name}"
        );
        assert_eq!(model.meta.param_shapes.len(), cfg.num_param_tensors());
    }
}

#[test]
fn pjrt_forward_matches_native_forward() {
    // Same parameters through (a) the AOT HLO predict executable and
    // (b) the native Rust forward must agree to f32 tolerance. This is the
    // core cross-validation that lets the native trainer stand in for the
    // PJRT path during hyperparameter search (DESIGN.md §1).
    let Some(rt) = runtime() else { return };
    let model = rt.load("quickstart").unwrap();
    let mut rng = Rng::new(42);
    let native = NativeModel::init(model.meta.cfg.clone(), &mut rng);
    let state = model.state_from_params(&native.params).unwrap();

    let mut rng2 = Rng::new(7);
    for case in 0..4 {
        let x = Tensor::from_vec(
            &[1, model.meta.window],
            (0..model.meta.window)
                .map(|_| rng2.gauss(0.0, 1.0) as f32)
                .collect(),
        );
        let pjrt = model.predict_one(&state, &x).unwrap();
        let native_out = native.forward(&x)[0];
        assert!(
            (pjrt - native_out).abs() <= 1e-4 + 1e-3 * native_out.abs(),
            "case {case}: pjrt {pjrt} vs native {native_out}"
        );
    }
}

#[test]
fn pjrt_training_reduces_loss() {
    // A few Adam steps through the AOT train executable must reduce the
    // loss on a fixed synthetic batch (the E2E example then does this on
    // real simulated DROPBEAR data).
    let Some(rt) = runtime() else { return };
    let model = rt.load("quickstart").unwrap();
    let mut state = model.init_state(3).unwrap();
    let b = model.meta.batch;
    let w = model.meta.window;
    let mut rng = Rng::new(5);
    let x = Tensor::from_vec(
        &[b, w],
        (0..b * w).map(|_| rng.gauss(0.0, 0.5) as f32).collect(),
    );
    let y: Vec<f32> = (0..b)
        .map(|i| x.row(i).iter().sum::<f32>() / w as f32)
        .collect();
    let first = model.train_step(&mut state, &x, &y).unwrap();
    let mut last = first;
    for _ in 0..40 {
        last = model.train_step(&mut state, &x, &y).unwrap();
    }
    assert!(
        last < first * 0.9,
        "PJRT training did not reduce loss: {first} -> {last}"
    );
    assert_eq!(state.steps, 41);
}

#[test]
fn pjrt_step_matches_native_step() {
    // One full Adam step: PJRT artifact vs native substrate, identical
    // params and batch. Verifies gradients + optimizer bit-for-bit
    // semantics (to f32 tolerance).
    let Some(rt) = runtime() else { return };
    let model = rt.load("quickstart").unwrap();
    let mut rng = Rng::new(11);
    let mut native = NativeModel::init(model.meta.cfg.clone(), &mut rng);
    let mut state = model.state_from_params(&native.params).unwrap();

    let b = model.meta.batch;
    let w = model.meta.window;
    let x = Tensor::from_vec(
        &[b, w],
        (0..b * w).map(|_| rng.gauss(0.0, 0.5) as f32).collect(),
    );
    let y: Vec<f32> = (0..b).map(|_| rng.gauss(0.0, 0.3) as f32).collect();

    let pjrt_loss = model.train_step(&mut state, &x, &y).unwrap();
    let mut opt = ntorc::nn::Adam::new(&native.params, ntorc::nn::AdamConfig::default());
    let native_loss = ntorc::nn::train_step(&mut native, &mut opt, &x, &y);
    assert!(
        (pjrt_loss - native_loss).abs() <= 1e-5 + 1e-4 * native_loss.abs(),
        "loss mismatch: {pjrt_loss} vs {native_loss}"
    );
    // Parameters after the step must agree.
    let pjrt_params = model.params_to_tensors(&state).unwrap();
    for (i, (a, b)) in pjrt_params.iter().zip(&native.params).enumerate() {
        assert_eq!(a.shape, b.shape, "param {i} shape");
        assert!(
            a.allclose(b, 5e-4, 5e-3),
            "param {i} diverged after one step (max|Δ| = {})",
            a.sub(b).max_abs()
        );
    }
}
