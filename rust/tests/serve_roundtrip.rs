//! Solve-once-serve-many, end to end (the PR's acceptance bar): two
//! `FrontierService` sessions over the same persistent store. The first
//! session builds and persists the frontier; the second answers a full
//! budget sweep WITHOUT ever invoking `ParetoFrontier::build` (its build
//! counter stays 0), and every answer is bit-identical to a fresh
//! `solve_bb` on the same problem.

use ntorc::coordinator::{Pipeline, PipelineConfig};
use ntorc::layers::NetConfig;
use ntorc::serve::{BatchOptions, BatchRequest, FrontierService, FrontierStore, ServeConfig};

fn temp_store(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("ntorc_serve_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        capacity: 4,
        workers: 1,
        max_choices_per_layer: 16,
        latency_budget: 50_000.0,
        max_points: None,
        epsilon: None,
        point_budget: None,
        latency_gamma: None,
        fifo_cost_per_slot: None,
        fifo_min_depth: 0.0,
        workload: None,
        backend: None,
    }
}

#[test]
fn second_session_serves_sweep_from_store_without_building() {
    let pipe = Pipeline::new(PipelineConfig::smoke());
    let db = pipe.synth_database();
    let models = pipe.fit_models(&db);
    let net = NetConfig::new(32, vec![(3, 4)], vec![], vec![8, 1]);
    let budgets: Vec<f64> = (1..=30).map(|i| 2_000.0 * i as f64).collect();
    let dir = temp_store("roundtrip");

    // Session 1: cold — builds the frontier once, persists it.
    let svc1 = FrontierService::new(serve_cfg(), Some(FrontierStore::new(&dir)));
    let first: Vec<_> = budgets.iter().map(|&b| svc1.query(&models, &net, b)).collect();
    let s1 = svc1.stats.snapshot();
    assert_eq!(s1.builds, 1, "one build for the whole sweep");
    assert_eq!(s1.store_hits, 0);
    assert!(first.iter().any(|s| s.is_some()), "sweep must have feasible budgets");

    // Session 2: a fresh service over the same store answers the whole
    // sweep with its build counter still at zero.
    let svc2 = FrontierService::new(serve_cfg(), Some(FrontierStore::new(&dir)));
    let second: Vec<_> = budgets.iter().map(|&b| svc2.query(&models, &net, b)).collect();
    let s2 = svc2.stats.snapshot();
    assert_eq!(s2.builds, 0, "second session must never invoke ParetoFrontier::build");
    assert_eq!(s2.store_hits, 1, "exactly one store load");
    assert_eq!(s2.mem_hits, budgets.len() as u64 - 1, "rest served from the LRU");
    assert_eq!(first, second, "answers must be identical across sessions");

    // ... and every answer is bit-identical to a fresh solve_bb on the
    // same problem (cross_check_bb re-solves each budget with B&B and
    // compares optimal cost + feasibility).
    let prob = models.build_problem(&net.plan(), 50_000.0, 16);
    let served = svc2.resolve(&models, &net);
    served
        .index
        .cross_check_bb(&prob, &budgets)
        .expect("frontier answers must reproduce fresh B&B solves");
    // Reuse factors served across sessions match the problem's choices.
    for sol in second.into_iter().flatten() {
        let reuse = served.reuse_of(&sol.pick);
        for (layer, (&j, &r)) in sol.pick.iter().zip(&reuse).enumerate() {
            assert_eq!(prob.layers[layer][j].reuse, r);
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn eps_frontier_round_trips_scoped_apart_from_exact() {
    // The ε-mode serving contract, end to end: an ε service persists its
    // coarsened frontier as a distinct document, a second ε session
    // answers from the store without building, every answer verifies
    // within the proven (1+ε)× bound of fresh B&B solves — and an exact
    // service over the SAME store never touches the ε document (zero
    // cross-mode hits, its own build, exact answers).
    let eps = 0.05;
    let eps_cfg = || ServeConfig { epsilon: Some(eps), ..serve_cfg() };
    let pipe = Pipeline::new(PipelineConfig::smoke());
    let db = pipe.synth_database();
    let models = pipe.fit_models(&db);
    let net = NetConfig::new(32, vec![(3, 4)], vec![], vec![8, 1]);
    let budgets: Vec<f64> = (1..=20).map(|i| 3_000.0 * i as f64).collect();
    let dir = temp_store("eps_roundtrip");

    // ε session 1: cold build, persisted under the ε-scoped key.
    let svc1 = FrontierService::new(eps_cfg(), Some(FrontierStore::new(&dir)));
    let first: Vec<_> = budgets.iter().map(|&b| svc1.query(&models, &net, b)).collect();
    assert_eq!(svc1.stats.snapshot().builds, 1);
    assert!(first.iter().any(|s| s.is_some()));

    // ε session 2: answers purely from the store, identically.
    let svc2 = FrontierService::new(eps_cfg(), Some(FrontierStore::new(&dir)));
    let second: Vec<_> = budgets.iter().map(|&b| svc2.query(&models, &net, b)).collect();
    let s2 = svc2.stats.snapshot();
    assert_eq!((s2.builds, s2.store_hits), (0, 1), "eps store must stay warm");
    assert_eq!(first, second, "eps answers identical across sessions");

    // The loaded document carries its bound and verifies within it
    // against fresh B&B re-solves.
    let served = svc2.resolve(&models, &net);
    assert_eq!(served.index.stats.epsilon, eps);
    let prob = models.build_problem(&net.plan(), 50_000.0, 16);
    served
        .index
        .cross_check_bb_within(&prob, &budgets, eps)
        .expect("eps answers must stay within (1+eps) of fresh B&B solves");

    // An exact service sharing the store: distinct key, own build, and
    // its answers reproduce B&B exactly — the ε document is invisible.
    let exact = FrontierService::new(serve_cfg(), Some(FrontierStore::new(&dir)));
    assert_ne!(exact.key_for(&net).hash, svc2.key_for(&net).hash);
    let _ = exact.query(&models, &net, 50_000.0);
    let se = exact.stats.snapshot();
    assert_eq!((se.builds, se.store_hits), (1, 0), "no cross-mode store hit");
    let exact_served = exact.resolve(&models, &net);
    assert_eq!(exact_served.index.stats.epsilon, 0.0);
    exact_served
        .index
        .cross_check_bb(&prob, &budgets)
        .expect("exact answers must reproduce fresh B&B solves");
    // Two documents now live side by side, eps-slugged apart.
    let store = FrontierStore::new(&dir);
    assert_eq!(store.list().len(), 2);
    assert!(store.contains(&svc2.model_key(&models, &net)));
    assert!(store.contains(&exact.model_key(&models, &net)));
    assert!(svc2.model_key(&models, &net).name.starts_with("eps-"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn batch_endpoint_serves_mixed_workload_across_sessions() {
    let pipe = Pipeline::new(PipelineConfig::smoke());
    let db = pipe.synth_database();
    let models = pipe.fit_models(&db);
    let nets = [
        NetConfig::new(32, vec![(3, 4)], vec![], vec![8, 1]),
        NetConfig::new(32, vec![], vec![4], vec![8, 1]),
    ];
    let mut requests = Vec::new();
    for i in 0..10 {
        requests.push(BatchRequest {
            net: nets[i % 2].clone(),
            budget: 10_000.0 + 20_000.0 * i as f64,
        });
    }
    let dir = temp_store("batch");

    let svc1 = FrontierService::new(serve_cfg(), Some(FrontierStore::new(&dir)));
    let cold = svc1.batch(&requests, &BatchOptions::models(&models));
    let s1 = svc1.stats.snapshot();
    assert_eq!(cold.len(), requests.len());
    assert_eq!(s1.builds, 2, "two unique architectures, two builds");
    assert_eq!(s1.mem_hits, 8);
    assert_eq!(s1.queries, 10);

    // A warm session answers the identical workload purely from disk +
    // LRU, and byte-for-byte identically.
    let svc2 = FrontierService::new(serve_cfg(), Some(FrontierStore::new(&dir)));
    let warm = svc2.batch(&requests, &BatchOptions::models(&models));
    let s2 = svc2.stats.snapshot();
    assert_eq!(s2.builds, 0);
    assert_eq!(s2.store_hits, 2);
    for (c, w) in cold.iter().zip(&warm) {
        assert_eq!(c.key, w.key);
        assert_eq!(c.budget, w.budget);
        assert_eq!(c.solution, w.solution);
    }
    let _ = std::fs::remove_dir_all(&dir);
}
